"""Materialized lineage views + the cell-level answer cache (ISSUE 7).

The contract under test: a store with views/caching enabled returns
**bit-identical** answers to the plain planner — after admission, after
in-place mutation, after drops, after new edges, and straight through a
crash-recovery replay — while hot routes plan over one composed hop and
repeated queries skip planning entirely.  The whole module runs under the
dynamic lock-order / race detector.
"""

import glob
import json
import os
import subprocess
import sys
import tempfile

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.capture import (
    flip_lineage,
    identity_lineage,
    roll_lineage,
    transpose_lineage,
)
from repro.core.catalog import DSLog
from repro.core.query import QueryBox, canonical_boxes, theta_join
from repro.core.shard import ShardedDSLog
from repro.core.views import (
    CompositionError,
    compose_route,
    compose_tables,
    is_view_id,
    view_id_of,
    view_pseudo_id,
)

SIDE = 8
SHAPE = (SIDE, SIDE)

VIEW_STATS = (
    "view_hits",
    "view_misses",
    "cache_hits",
    "cache_misses",
    "views_materialized",
    "views_invalidated",
)


@pytest.fixture(autouse=True)
def _race_detect(race_detector):
    """Whole module runs under the dynamic lock-order / race detector."""
    yield


_OPS = [
    lambda rng: identity_lineage(SHAPE),
    lambda rng: flip_lineage(SHAPE, int(rng.integers(0, 2))),
    lambda rng: roll_lineage(SHAPE, int(rng.integers(1, 4)), 0),
    lambda rng: transpose_lineage(SHAPE, (1, 0)),
]

_CHAIN_OPS = [
    flip_lineage(SHAPE, 0),
    roll_lineage(SHAPE, 2, 0),
    transpose_lineage(SHAPE, (1, 0)),
    identity_lineage(SHAPE),
    flip_lineage(SHAPE, 1),
]


def _chain(log, ops=None, prefix="a"):
    """Linear chain prefix0 -> prefix1 -> ... with deterministic ops."""
    ops = _CHAIN_OPS if ops is None else ops
    log.define_array(f"{prefix}0", SHAPE)
    for k, rel in enumerate(ops):
        new = f"{prefix}{k + 1}"
        log.define_array(new, SHAPE)
        log.add_lineage(f"{prefix}{k}", new, rel, op_name=f"op_{prefix}{k}")
    return [f"{prefix}{k}" for k in range(len(ops) + 1)]


def _oracle(build):
    log = DSLog()
    build(log)
    log.views.enabled = False
    return log


def _build_random_dag(logs, n_ops, seed):
    """Identical op stream into several stores: chain backbone plus a
    fan-in every third op (same shape as tests/test_shard.py)."""
    rng = np.random.default_rng(seed)
    names = ["a0"]
    for log in logs:
        log.define_array("a0", SHAPE)
    for k in range(n_ops):
        new = f"a{k + 1}"
        rel = _OPS[int(rng.integers(0, len(_OPS)))](rng)
        extra = None
        if k % 3 == 2 and len(names) > 2:
            other = names[int(rng.integers(0, len(names) - 1))]
            extra = (other, _OPS[int(rng.integers(0, len(_OPS)))](rng))
        for log in logs:
            log.define_array(new, SHAPE)
            log.add_lineage(names[-1], new, rel, op_name=f"op{k}")
            if extra is not None:
                log.add_lineage(extra[0], new, extra[1], op_name=f"op{k}b")
        names.append(new)
    return names


def _assert_identical(got: QueryBox, want: QueryBox, ctx=""):
    assert got.shape == want.shape, ctx
    assert got.lo.tobytes() == want.lo.tobytes(), ctx
    assert got.hi.tobytes() == want.hi.tobytes(), ctx


# ------------------------------------------------------------------------- #
# pseudo ids + composition algebra
# ------------------------------------------------------------------------- #


def test_pseudo_id_roundtrip():
    for vid in (0, 1, 7, 10_000):
        pid = view_pseudo_id(vid)
        assert pid < 0 and is_view_id(pid)
        assert view_id_of(pid) == vid
    assert not is_view_id(0) and not is_view_id(42)


def test_compose_two_hops_exact():
    """compose(t2, t1) answers every query like the two-hop chain."""
    rng = np.random.default_rng(0)
    rels = [f(rng) for f in _OPS] + [flip_lineage(SHAPE, 1)]
    qboxes = [
        QueryBox.from_cells(SHAPE, np.array([[0, 0]])),
        QueryBox.from_cells(SHAPE, np.array([[3, 5], [7, 1]])),
        QueryBox.full(SHAPE),
    ]
    for i, ra in enumerate(rels):
        for j, rb in enumerate(rels):
            log = DSLog()
            log.views.enabled = False
            log.define_array("x", SHAPE)
            log.define_array("y", SHAPE)
            log.define_array("z", SHAPE)
            e1 = log.add_lineage("x", "y", ra)
            e2 = log.add_lineage("y", "z", rb)
            t1, t2 = e1.backward, e2.backward
            comp = compose_tables(t2, t1)
            for q in qboxes:
                want = theta_join(theta_join(q, t2), t1).cell_set()
                got = theta_join(q, comp).cell_set()
                assert got == want, (i, j)


def test_compose_route_row_cap():
    rng = np.random.default_rng(1)
    log = DSLog()
    log.views.enabled = False
    _chain(log)
    tabs = [log.lineage[lid].backward for lid in sorted(log.lineage)][::-1]
    with pytest.raises(CompositionError):
        compose_route(tabs, max_rows=1, direction="backward")
    comp = compose_route(tabs, max_rows=10_000, direction="backward")
    q = QueryBox.from_cells(SHAPE, rng.integers(0, SIDE, size=(3, 2)))
    want = q
    for t in tabs:
        want = theta_join(want, t)
    assert theta_join(q, comp).cell_set() == want.cell_set()


def test_canonical_boxes_decomposition_invariant():
    """canonical_boxes is a function of the cell set alone."""
    rng = np.random.default_rng(2)
    for _ in range(20):
        cells = rng.integers(0, SIDE, size=(int(rng.integers(1, 12)), 2))
        q = QueryBox.from_cells(SHAPE, cells)
        # a second decomposition of the same set: per-cell singletons,
        # duplicated and shuffled
        dup = np.repeat(cells, 2, axis=0)
        rng.shuffle(dup)
        q2 = QueryBox.from_cells(SHAPE, dup)
        c1, c2 = canonical_boxes(q), canonical_boxes(q2)
        assert c1.cell_set() == q.cell_set()
        _assert_identical(c1, c2)


# ------------------------------------------------------------------------- #
# heat-driven admission + the planner cost race
# ------------------------------------------------------------------------- #


def test_view_admission_plan_and_bit_identity():
    log = DSLog()
    _chain(log)
    oracle = _oracle(_chain)
    rng = np.random.default_rng(3)
    for i in range(10):
        cells = rng.integers(0, SIDE, size=(2, 2))
        _assert_identical(
            log.prov_query("a5", "a0", cells),
            oracle.prov_query("a5", "a0", cells),
            f"query {i}",
        )
    st = log.io_stats
    assert st["views_materialized"] == 1
    assert st["view_hits"] >= 5
    plan = log.planner.plan("a5", ["a0"])
    assert "view#" in plan.describe()
    # the same stored view serves the forward direction
    for i in range(3):
        cells = rng.integers(0, SIDE, size=(1, 2))
        _assert_identical(
            log.prov_query("a0", "a5", cells),
            oracle.prov_query("a0", "a5", cells),
            f"fwd {i}",
        )
    assert log.io_stats["views_materialized"] == 1


def test_single_hop_routes_never_materialize():
    log = DSLog()
    _chain(log, ops=_CHAIN_OPS[:1])
    rng = np.random.default_rng(4)
    for _ in range(10):
        log.prov_query("a1", "a0", rng.integers(0, SIDE, size=(1, 2)))
    assert log.io_stats["views_materialized"] == 0
    assert len(log.views.views) == 0


def test_budget_lru_demotion():
    def build(log):
        _chain(log, prefix="a")
        _chain(log, prefix="b")

    log = DSLog()
    build(log)
    rng = np.random.default_rng(5)
    for _ in range(6):
        log.prov_query("a5", "a0", rng.integers(0, SIDE, size=(2, 2)))
    assert len(log.views.views) == 1
    only = next(iter(log.views.views.values()))
    log.views.budget_rows = only.total_rows  # no room for a second view
    for _ in range(6):
        log.prov_query("b5", "b0", rng.integers(0, SIDE, size=(2, 2)))
    assert len(log.views.views) == 1  # coldest (route a) demoted
    survivor = next(iter(log.views.views.values()))
    assert (survivor.src, survivor.dst) == ("b0", "b5")


# ------------------------------------------------------------------------- #
# answer cache
# ------------------------------------------------------------------------- #


def test_answer_cache_hit_and_lru_eviction():
    log = DSLog()
    _chain(log)
    oracle = _oracle(_chain)
    cells = np.array([[2, 3], [4, 4]])
    first = log.prov_query("a5", "a0", cells)
    for _ in range(3):
        _assert_identical(log.prov_query("a5", "a0", cells), first)
    st = log.io_stats
    assert st["cache_hits"] == 3 and st["cache_misses"] == 1
    _assert_identical(first, oracle.prov_query("a5", "a0", cells))
    # capacity bound: oldest answers fall off
    log.views.cache_capacity = 4
    for r in range(SIDE):
        log.prov_query("a5", "a0", np.array([[r, 0]]))
    assert len(log.views._cache) == 4
    # unmerged answers are never cached
    before = log.io_stats["cache_misses"]
    log.prov_query("a5", "a0", cells, merge=False)
    assert log.io_stats["cache_misses"] == before


# ------------------------------------------------------------------------- #
# precise invalidation
# ------------------------------------------------------------------------- #


def _two_chains(log):
    _chain(log, prefix="a")
    _chain(log, prefix="b")


def _heat_both(log, rng):
    for _ in range(6):
        log.prov_query("a5", "a0", rng.integers(0, SIDE, size=(2, 2)))
        log.prov_query("b5", "b0", rng.integers(0, SIDE, size=(2, 2)))
    assert len(log.views.views) == 2


def test_mark_dirty_kills_only_touching_route():
    log = DSLog()
    _two_chains(log)
    _heat_both(log, np.random.default_rng(6))
    answers_before = len(log.views._cache)
    lid = log.by_pair[("b2", "b3")][0]
    log.mark_dirty(lid)
    routes = {(v.src, v.dst) for v in log.views.views.values()}
    assert routes == {("a0", "a5")}
    assert log.io_stats["views_invalidated"] == 1
    # only route-b answers were purged
    left = log.views._cache
    assert 0 < len(left) < answers_before
    assert all(e["src"].startswith("a") for e in left.values())


def test_drop_lineage_kills_only_touching_route():
    log = DSLog()
    _two_chains(log)
    _heat_both(log, np.random.default_rng(7))
    log.drop_lineage(log.by_pair[("a1", "a2")][0])
    routes = {(v.src, v.dst) for v in log.views.views.values()}
    assert routes == {("b0", "b5")}


def test_new_edge_on_route_invalidates_off_route_does_not():
    log = DSLog()
    _two_chains(log)
    _heat_both(log, np.random.default_rng(8))
    # extend chain a past its endpoint: both views survive
    log.define_array("a6", SHAPE)
    log.add_lineage("a5", "a6", identity_lineage(SHAPE))
    routes = {(v.src, v.dst) for v in log.views.views.values()}
    assert routes == {("a0", "a5"), ("b0", "b5")}
    # a parallel edge inside route b kills exactly that view
    log.add_lineage("b2", "b3", flip_lineage(SHAPE, 1))
    routes = {(v.src, v.dst) for v in log.views.views.values()}
    assert routes == {("a0", "a5")}
    # and the next hot streak re-materializes a correct replacement
    oracle = DSLog()
    _two_chains(oracle)
    oracle.add_lineage("b2", "b3", flip_lineage(SHAPE, 1))
    oracle.views.enabled = False
    rng = np.random.default_rng(9)
    for i in range(6):
        cells = rng.integers(0, SIDE, size=(2, 2))
        _assert_identical(
            log.prov_query("b5", "b0", cells),
            oracle.prov_query("b5", "b0", cells),
            f"re-materialized {i}",
        )


# ------------------------------------------------------------------------- #
# property: bit-identical to the plain planner on random DAGs
# ------------------------------------------------------------------------- #


@settings(max_examples=8, deadline=None)
@given(
    n_ops=st.integers(4, 9),
    seed=st.integers(0, 10_000),
    kind=st.sampled_from(["dslog", "shard1", "shard4"]),
)
def test_views_bit_identical_on_random_dags(n_ops, seed, kind):
    if kind == "dslog":
        log = DSLog()
    else:
        log = ShardedDSLog(n_shards=1 if kind == "shard1" else 4)
    oracle = DSLog()
    oracle.views.enabled = False
    names = _build_random_dag([log, oracle], n_ops, seed)
    src, dst = names[-1], names[0]
    rng = np.random.default_rng(seed + 1)

    def check(tag):
        for i in range(6):
            cells = rng.integers(0, SIDE, size=(int(rng.integers(1, 4)), 2))
            _assert_identical(
                log.prov_query(src, dst, cells),
                oracle.prov_query(src, dst, cells),
                f"{tag} bwd {i}",
            )
        cells = rng.integers(0, SIDE, size=(1, 2))
        _assert_identical(
            log.prov_query(dst, src, cells),
            oracle.prov_query(dst, src, cells),
            f"{tag} fwd",
        )
        repeat = rng.integers(0, SIDE, size=(2, 2))
        _assert_identical(
            log.prov_query(src, dst, repeat),
            log.prov_query(src, dst, repeat),  # second hit: from the cache
            f"{tag} cached",
        )

    check("warm-up")
    # immediately after an in-place mutation (lid spaces differ between the
    # sharded store and the oracle, so pick the victim by pair)
    pairs = sorted(log.by_pair)
    pair = pairs[int(rng.integers(0, len(pairs)))]
    log.mark_dirty(log.by_pair[pair][0])
    oracle.mark_dirty(oracle.by_pair[pair][0])
    check("after mark_dirty")
    # immediately after dropping a fan-in entry (keeps the route alive)
    fanin = [(s, d) for (s, d) in pairs if s != f"a{int(d[1:]) - 1}"]
    if fanin:
        s, d = fanin[0]
        log.drop_lineage(log.by_pair[(s, d)][0])
        oracle.drop_lineage(oracle.by_pair[(s, d)][0])
        check("after drop_lineage")


@pytest.mark.parametrize("kind", ["dslog", "shard4"])
def test_views_bit_identical_through_crash_recovery(kind):
    """Views/answers persisted by save(), then a mutation that only the WAL
    records: the reloaded store must answer like a plain rebuilt oracle."""
    seed = 11
    with tempfile.TemporaryDirectory() as d:
        root = os.path.join(d, "s")
        if kind == "dslog":
            log = DSLog.open(root, durability="sync")
        else:
            log = ShardedDSLog.open(root, 4, durability="sync")
        oracle = DSLog()
        oracle.views.enabled = False
        names = _build_random_dag([log, oracle], 6, seed)
        src, dst = names[-1], names[0]
        rng = np.random.default_rng(seed)
        for _ in range(6):
            log.prov_query(src, dst, rng.integers(0, SIDE, size=(2, 2)))
        assert log.views.views  # a view was admitted and will persist
        log.save()
        pairs = sorted(log.by_pair)
        pair = pairs[int(rng.integers(0, len(pairs)))]
        log.mark_dirty(log.by_pair[pair][0])
        oracle.mark_dirty(oracle.by_pair[pair][0])
        log.commit()
        log.close(checkpoint=False)  # crash: manifest still lists the view

        re = (DSLog if kind == "dslog" else ShardedDSLog).load(root)
        assert not re.views.views  # replay killed the stale view
        for i in range(4):
            cells = rng.integers(0, SIDE, size=(2, 2))
            _assert_identical(
                re.prov_query(src, dst, cells),
                oracle.prov_query(src, dst, cells),
                f"post-recovery {i}",
            )


# ------------------------------------------------------------------------- #
# persistence
# ------------------------------------------------------------------------- #


@pytest.mark.parametrize("kind", ["dslog", "shard4"])
def test_view_persistence_roundtrip(kind):
    with tempfile.TemporaryDirectory() as d:
        root = os.path.join(d, "s")
        if kind == "dslog":
            log = DSLog(root=root)
        else:
            log = ShardedDSLog(n_shards=4, root=root)
        _chain(log)
        oracle = _oracle(_chain)
        rng = np.random.default_rng(10)
        qs = [rng.integers(0, SIDE, size=(2, 2)) for _ in range(6)]
        for q in qs:
            log.prov_query("a5", "a0", q)
        assert len(log.views.views) == 1
        log.save()
        assert glob.glob(os.path.join(root, "view_*.prvc"))
        assert os.path.exists(os.path.join(root, "answers.json"))

        re = (DSLog if kind == "dslog" else ShardedDSLog).load(root)
        assert len(re.views.views) == 1
        # a persisted answer serves with no planning and no table loads
        _assert_identical(
            re.prov_query("a5", "a0", qs[-1]),
            oracle.prov_query("a5", "a0", qs[-1]),
        )
        assert re.io_stats["cache_hits"] == 1
        assert re.io_stats["tables_loaded"] == 0
        # fresh cells route through the reloaded view blob, not a recompose
        _assert_identical(
            re.prov_query("a5", "a0", np.array([[0, 0]])),
            oracle.prov_query("a5", "a0", np.array([[0, 0]])),
        )
        assert re.io_stats["views_materialized"] == 0
        assert re.io_stats["view_hits"] >= 1
        # clean re-save never rewrites view blobs
        written = re.io_stats["tables_written"]
        re.save()
        assert re.io_stats["tables_written"] == written
        re.compact()
        again = (DSLog if kind == "dslog" else ShardedDSLog).load(root)
        assert len(again.views.views) == 1  # vacuum kept referenced blobs


def test_torn_answer_sidecar_starts_cold():
    with tempfile.TemporaryDirectory() as d:
        root = os.path.join(d, "s")
        log = DSLog(root=root)
        _chain(log)
        log.prov_query("a5", "a0", np.array([[1, 1]]))
        log.save()
        with open(os.path.join(root, "answers.json"), "w") as f:
            f.write('{"answers": [{"key"')  # torn mid-write
        re = DSLog.load(root)
        assert len(re.views._cache) == 0
        re.prov_query("a5", "a0", np.array([[1, 1]]))  # still answers


def test_invalidated_view_blob_is_vacuumed():
    with tempfile.TemporaryDirectory() as d:
        root = os.path.join(d, "s")
        log = DSLog(root=root)
        _chain(log)
        rng = np.random.default_rng(12)
        for _ in range(6):
            log.prov_query("a5", "a0", rng.integers(0, SIDE, size=(2, 2)))
        log.save()
        blobs = set(glob.glob(os.path.join(root, "view_*")))
        assert blobs
        log.mark_dirty(log.by_pair[("a2", "a3")][0])
        log.save()  # dirty-tracked saves never delete
        assert set(glob.glob(os.path.join(root, "view_*"))) == blobs
        stats = log.compact()
        assert stats["files_removed"] >= len(blobs)
        assert not glob.glob(os.path.join(root, "view_*"))


# ------------------------------------------------------------------------- #
# fsck integration
# ------------------------------------------------------------------------- #


def _fsck(root):
    proc = subprocess.run(
        [sys.executable, "-m", "repro.tools.fsck", root, "--json"],
        capture_output=True,
        text=True,
        env={**os.environ, "PYTHONPATH": "src"},
        cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
    )
    return proc.returncode, json.loads(proc.stdout)


@pytest.mark.parametrize("kind", ["dslog", "shard4"])
def test_fsck_views_clean_and_stale(kind):
    with tempfile.TemporaryDirectory() as d:
        root = os.path.join(d, "s")
        if kind == "dslog":
            log = DSLog.open(root, durability="sync")
        else:
            log = ShardedDSLog.open(root, 4, durability="sync")
        _chain(log)
        rng = np.random.default_rng(13)
        for _ in range(6):
            log.prov_query("a5", "a0", rng.integers(0, SIDE, size=(2, 2)))
        log.save()
        lid = log.by_pair[("a2", "a3")][0]

        rc, rep = _fsck(root)
        assert rc == 0 and rep["checked"]["views"] == 1, rep

        log.mark_dirty(lid)  # WAL-only mutation: the persisted view is stale
        log.commit()
        log.close(checkpoint=False)
        rc, rep = _fsck(root)
        cats = {f["rule"] for f in rep["findings"]}
        assert rc == 1 and "view-stale" in cats, rep

        # recovery folds the invalidation back in; a checkpoint then leaves
        # orphaned view blobs that compact() reclaims — fsck tracks both
        re = (DSLog if kind == "dslog" else ShardedDSLog).load(root)
        assert not re.views.views
        re.save()
        rc, rep = _fsck(root)
        cats = {f["rule"] for f in rep["findings"]}
        assert rc == 0 and "view-stale" not in cats, rep
        assert "orphan-blob" in cats, rep
        re.compact()
        rc, rep = _fsck(root)
        assert rc == 0 and "orphan-blob" not in {
            f["rule"] for f in rep["findings"]
        }, rep


def test_fsck_flags_missing_view_blob():
    with tempfile.TemporaryDirectory() as d:
        root = os.path.join(d, "s")
        log = DSLog(root=root)
        _chain(log)
        rng = np.random.default_rng(14)
        for _ in range(6):
            log.prov_query("a5", "a0", rng.integers(0, SIDE, size=(2, 2)))
        log.save()
        victim = glob.glob(os.path.join(root, "view_*.prvc"))[0]
        os.remove(victim)
        rc, rep = _fsck(root)
        assert rc == 1
        assert any(
            f["rule"] == "dangling-handle" and "view_" in f["path"]
            for f in rep["findings"]
        ), rep
