"""Every registry op: valid lineage, lossless compression, sane categories."""

import numpy as np
import pytest

from repro.core.oplib import OPS, op_names
from repro.core.provrc import compress


def test_registry_size_and_split():
    assert len(OPS) >= 120
    el = sum(1 for s in OPS.values() if s.category == "element")
    cx = sum(1 for s in OPS.values() if s.category == "complex")
    assert el >= 70 and cx >= 45


@pytest.mark.parametrize("name", op_names())
def test_op_lossless(name):
    spec = OPS[name]
    rng = np.random.default_rng(0)
    rels = spec.lineage(spec.shapes[0], rng)
    assert rels, name
    for _, rel in rels.items():
        t = compress(rel, method="vector")
        assert t.decompress() == rel, name


@pytest.mark.parametrize("name", ["negative", "add", "matmul", "sum", "tile"])
def test_structured_ops_compress_small(name):
    spec = OPS[name]
    rng = np.random.default_rng(0)
    rels = spec.lineage(spec.shapes[0], rng)
    for _, rel in rels.items():
        t = compress(rel, method="vector")
        assert t.n_rows <= 4


def test_cross_is_flagged_pattern_dependent():
    assert OPS["cross"].shape_pattern_dependent
    assert not OPS["negative"].shape_pattern_dependent
