"""Telemetry subsystem: registry, histograms, tracing, export, EXPLAIN ANALYZE.

Covers the observability invariants end to end: instrument math
(log-bucketed percentiles, snapshot merge), the live ``io_stats`` facade,
the per-query span tree (plan / hop / kernel / exchange / cache / view),
``describe(analyze=True)``, the ``telemetry.json`` sidecar + Prometheus
exposition + ``dstat`` CLI, health red-flags, the sharded aggregation
union fix, tracing on/off bit-identity under the race detector, and a
bound on the tracing-off instrument cost.
"""

import json
import os
import tempfile
import time

import numpy as np
import pytest

from repro.core.capture import (
    flip_lineage,
    identity_lineage,
    roll_lineage,
    transpose_lineage,
)
from repro.core.catalog import DSLog
from repro.core.shard import ShardedDSLog
from repro.obs.export import (
    TELEMETRY_SCHEMA,
    parse_prometheus,
    render_prometheus,
    telemetry_snapshot,
    validate_telemetry,
)
from repro.obs.metrics import Histogram, MetricsRegistry, bucket_index
from repro.obs.trace import QueryTrace, maybe_span
from repro.tools import dstat

SIDE = 8
SHAPE = (SIDE, SIDE)

_OPS = [
    lambda rng: identity_lineage(SHAPE),
    lambda rng: flip_lineage(SHAPE, int(rng.integers(0, 2))),
    lambda rng: roll_lineage(SHAPE, int(rng.integers(1, 4)), 0),
    lambda rng: transpose_lineage(SHAPE, (1, 0)),
]


def _build_random_dag(logs, n_ops: int, seed: int):
    """Drive identical op streams into several stores (see test_shard)."""
    rng = np.random.default_rng(seed)
    names = ["a0"]
    for log in logs:
        log.define_array("a0", SHAPE)
    for k in range(n_ops):
        new = f"a{k + 1}"
        prev = names[-1]
        fan_in = k % 3 == 2 and len(names) > 2
        if fan_in:
            other = names[int(rng.integers(0, len(names) - 1))]
            state = rng.bit_generator.state
            for log in logs:
                rng.bit_generator.state = state
                rel_a = _OPS[int(rng.integers(0, len(_OPS)))](rng)
                rel_b = _OPS[int(rng.integers(0, len(_OPS)))](rng)
                log.define_array(new, SHAPE)
                log.register_operation(
                    f"op{k}", [prev, other], [new],
                    capture=lambda ra=rel_a, rb=rel_b: {(0, 0): ra, (0, 1): rb},
                    reuse=False,
                )
        else:
            state = rng.bit_generator.state
            for log in logs:
                rng.bit_generator.state = state
                rel = _OPS[int(rng.integers(0, len(_OPS)))](rng)
                log.define_array(new, SHAPE)
                log.register_operation(
                    f"op{k}", [prev], [new],
                    capture=lambda r=rel: {(0, 0): r},
                    reuse=False,
                )
        names.append(new)
    return names


def _one_hop(log):
    log.add_lineage("A", "B", identity_lineage(SHAPE))
    return log


# --------------------------------------------------------------------------- #
# histogram + registry units
# --------------------------------------------------------------------------- #
def test_histogram_percentiles_bracket_samples():
    h = Histogram()
    values = [0.001 * (i + 1) for i in range(100)]
    for v in values:
        h.observe(v)
    d = h.to_dict()
    assert d["count"] == 100
    assert d["min"] == pytest.approx(0.001)
    assert d["max"] == pytest.approx(0.1)
    # geometric buckets: each percentile within a 2x factor of the exact
    # order statistic, and ordered
    assert 0.04 <= d["p50"] <= 0.11
    assert d["p50"] <= d["p90"] <= d["p99"] <= d["max"]
    assert d["sum"] == pytest.approx(sum(values))


def test_histogram_merge_equals_combined_stream():
    rng = np.random.default_rng(3)
    a, b, both = Histogram(), Histogram(), Histogram()
    for v in rng.uniform(1e-6, 1e-2, 500):
        a.observe(float(v)); both.observe(float(v))
    for v in rng.uniform(1e-4, 1.0, 500):
        b.observe(float(v)); both.observe(float(v))
    a.merge(b)
    da, dboth = a.to_dict(), both.to_dict()
    assert da["count"] == dboth["count"] == 1000
    assert da["buckets"] == dboth["buckets"]
    assert da["p99"] == pytest.approx(dboth["p99"])
    assert da["min"] == dboth["min"] and da["max"] == dboth["max"]


def test_bucket_index_is_monotone():
    idxs = [bucket_index(10.0 ** e) for e in range(-9, 3)]
    assert idxs == sorted(idxs)
    assert bucket_index(1e-9) <= bucket_index(2e-9) <= bucket_index(4e-9)


def test_registry_labeled_counters_fold_into_flat_view():
    reg = MetricsRegistry("t")
    reg.inc("queries", 2, path="cache")
    reg.inc("queries", 3, path="planned")
    reg.inc("queries")  # unlabeled base series
    assert reg.counters_flat()["queries"] == 6
    assert reg.counter_value("queries", path="cache") == 2


def test_merge_snapshots_unions_novel_keys():
    a, b = MetricsRegistry("a"), MetricsRegistry("b")
    a.inc("shared", 1)
    b.inc("shared", 2)
    b.inc("only_in_b", 7)
    b.observe("lat", 0.5)
    merged = MetricsRegistry.merge_snapshots([a.snapshot(), b.snapshot()])
    counters = {(r["name"], tuple(sorted(r["labels"].items()))): r["value"]
                for r in merged["counters"]}
    assert counters[("shared", ())] == 3
    assert counters[("only_in_b", ())] == 7
    assert [h["name"] for h in merged["histograms"]] == ["lat"]


# --------------------------------------------------------------------------- #
# trace span trees
# --------------------------------------------------------------------------- #
def test_trace_covers_plan_hop_kernel_cache_view(race_detector):
    log = _one_hop(DSLog())
    res, tr = log.prov_query("B", "A", np.array([[2, 2]]), trace=True)
    assert res.cell_set() == {(2, 2)}
    kinds = tr.kinds()
    for kind in ("query", "cache", "plan", "view", "execute", "kernel", "hop"):
        assert kind in kinds, f"missing span kind {kind!r} in {sorted(kinds)}"
    hop = tr.spans(kind="hop")[0]
    assert hop.attrs["u"] == "B" and hop.attrs["v"] == "A"
    assert hop.attrs["qrows"] >= 1 and hop.attrs["pairs"] >= 1
    # the root aggregates instrument deltas from the whole query
    root = tr.root
    assert root.duration > 0
    rendered = tr.render()
    assert "plan" in rendered and "hop" in rendered


def test_trace_exchange_events_on_sharded_store(race_detector):
    sl = ShardedDSLog(n_shards=4)
    _build_random_dag([sl], n_ops=6, seed=11)
    res, tr = sl.prov_query("a6", "a0", np.array([[3, 3]]), trace=True)
    assert "exchange" in tr.kinds()
    ex = tr.spans(kind="exchange")[0]
    assert ex.attrs["from_shard"] != ex.attrs["to_shard"]
    assert ex.attrs["boxes"] >= 1
    # the per-shard-pair labeled counter moved with it
    pair_total = sum(
        row["value"]
        for row in sl.metrics_snapshot()["counters"]
        if row["name"] == "exchange_boxes" and row["labels"]
    )
    assert pair_total >= ex.attrs["boxes"]


def test_trace_cache_hit_path_labels(race_detector):
    log = _one_hop(DSLog())
    cells = np.array([[1, 1]])
    log.prov_query("B", "A", cells)
    _, tr = log.prov_query("B", "A", cells, trace=True)
    probe = tr.spans(kind="cache")[0]
    assert probe.attrs["hit"] is True
    assert log.metrics.counter_value("queries", path="cache") == 1
    assert log.metrics.counter_value("queries", path="planned") == 1


def test_trace_off_installs_nothing():
    log = _one_hop(DSLog())
    res = log.prov_query("B", "A", np.array([[2, 2]]))
    assert res.cell_set() == {(2, 2)}
    assert log._active_trace is None


def test_maybe_span_null_path_and_real_path():
    with maybe_span(None, "x", kind="plan") as sp:
        sp.attrs["anything"] = 1  # writes on the null span are swallowed
    tr = QueryTrace()
    with maybe_span(tr, "x", kind="plan") as sp:
        sp.attrs["est"] = 4
    tr.finish()
    assert tr.spans(kind="plan")[0].attrs["est"] == 4


# --------------------------------------------------------------------------- #
# EXPLAIN ANALYZE
# --------------------------------------------------------------------------- #
def test_describe_analyze_reports_est_vs_measured():
    log = _one_hop(DSLog())
    plan = log.planner.plan("B", "A")
    assert "not executed" in plan.describe(analyze=True)
    boxes = log._as_boxes("B", [np.array([[2, 2]])])
    log.planner.execute(plan, boxes)
    txt = plan.describe(analyze=True)
    assert "est_pairs=" in txt and "measured pairs=" in txt
    assert "not executed" not in txt
    assert "measured exec=" in txt  # packed-dispatch wall time in header
    # plain describe is unchanged (no measured sublines)
    assert "measured" not in plan.describe()


def test_describe_analyze_serial_engine_times_each_hop():
    log = _one_hop(DSLog())
    plan = log.planner.plan("B", "A", batched=False)
    boxes = log._as_boxes("B", [np.array([[2, 2]])])
    log.planner.execute(plan, boxes, batched=False)
    assert "time=" in plan.describe(analyze=True)


def test_sharded_describe_analyze_includes_exchanges(race_detector):
    sl = ShardedDSLog(n_shards=4)
    _build_random_dag([sl], n_ops=6, seed=11)
    sl.prov_query("a6", "a0", np.array([[3, 3]]))
    plan = sl.views.plan_get("a6", ("a0",), None) or sl.planner.plan("a6", "a0")
    txt = plan.describe(analyze=True)
    assert "est_pairs=" in txt


# --------------------------------------------------------------------------- #
# io_stats facade + sharded union (satellite regression)
# --------------------------------------------------------------------------- #
def test_io_stats_view_is_live_and_read_only():
    log = _one_hop(DSLog())
    before = log.io_stats["kernel_launches"]
    log.prov_query("B", "A", np.array([[2, 2]]))
    assert log.io_stats["kernel_launches"] > before
    with pytest.raises(TypeError):
        log.io_stats["kernel_launches"] = 0
    assert set(dict(log.io_stats)) == set(log.io_stats)


def test_sharded_io_stats_unions_shard_minted_counters(race_detector):
    sl = ShardedDSLog(n_shards=2)
    _build_random_dag([sl], n_ops=4, seed=5)
    # a counter no registry seeds: minted only inside one shard (the bug
    # was aggregating over a hardcoded key list, dropping these)
    sl.shard(0).metrics.inc("wal_replayed", 3)
    sl.shard(1).metrics.inc("exchange_boxes", 2, from_shard="1", to_shard="0")
    stats = sl.io_stats
    assert stats["wal_replayed"] == 3
    assert stats["exchange_boxes"] >= 2  # labeled series fold into the base
    # facade-minted counters still present
    assert "shards_loaded" in stats


def test_sharded_metrics_snapshot_merges_all_registries(race_detector):
    sl = ShardedDSLog(n_shards=2)
    _build_random_dag([sl], n_ops=4, seed=5)
    sl.prov_query("a4", "a0", np.array([[1, 1]]))
    snap = sl.metrics_snapshot()
    assert snap["registry"] == "dslog-root"
    names = {r["name"] for r in snap["counters"]}
    assert "kernel_launches" in names  # shard-side work
    assert "queries" in names  # facade-side work


# --------------------------------------------------------------------------- #
# sidecar, exporters, CLI, health
# --------------------------------------------------------------------------- #
def _store_with_traffic(d):
    log = DSLog.open(os.path.join(d, "s"))
    _one_hop(log)
    log.prov_query("B", "A", np.array([[2, 2]]))
    log.prov_query("B", "A", np.array([[2, 2]]))  # cache hit
    log.save()
    return log


def test_telemetry_sidecar_schema_and_percentiles():
    with tempfile.TemporaryDirectory() as d:
        log = _store_with_traffic(d)
        try:
            path = os.path.join(d, "s", "telemetry.json")
            snap = json.loads(open(path).read())
            counts = validate_telemetry(snap)
            assert counts["counters"] > 0 and counts["histograms"] > 0
            assert snap["schema"] == TELEMETRY_SCHEMA
            hists = {h["name"] for h in snap["histograms"]}
            assert "wal_fsync_seconds" in hists
            assert "query_seconds" in hists
            qs = [h for h in snap["histograms"] if h["name"] == "query_seconds"]
            assert all(h["labels"].get("path") for h in qs)
            assert all(h["p50"] <= h["p99"] <= h["max"] * 2 for h in qs)
        finally:
            log.close()


def test_telemetry_sidecar_not_restored_on_load():
    with tempfile.TemporaryDirectory() as d:
        _store_with_traffic(d).close()
        re = DSLog.load(os.path.join(d, "s"))
        assert re.io_stats["tables_loaded"] == 0
        assert re.io_stats["cache_hits"] == 0


def test_prometheus_render_and_parse_roundtrip():
    log = _one_hop(DSLog())
    log.prov_query("B", "A", np.array([[2, 2]]))
    log.metrics.observe("query_seconds", 0.01, path="planned", engine="batched")
    text = render_prometheus(telemetry_snapshot(log))
    assert parse_prometheus(text) > 10
    assert "dslog_kernel_launches_total" in text
    assert 'le="+Inf"' in text


def test_validate_telemetry_rejects_malformed():
    with pytest.raises(ValueError):
        validate_telemetry({"schema": "nope"})
    with pytest.raises(ValueError):
        validate_telemetry(
            {"schema": TELEMETRY_SCHEMA, "store": "X", "registry": "r",
             "counters": [{"name": 3, "labels": {}, "value": 1}],
             "gauges": [], "histograms": []}
        )
    with pytest.raises(ValueError):
        parse_prometheus("bad{unterminated 3\n")


def test_dstat_cli_dump_diff(capsys):
    with tempfile.TemporaryDirectory() as d:
        log = _store_with_traffic(d)
        root = os.path.join(d, "s")
        try:
            assert dstat.main(["dump", root, "--json"]) == 0
            snap = json.loads(capsys.readouterr().out)
            validate_telemetry(snap)

            assert dstat.main(["dump", root, "--prometheus"]) == 0
            assert parse_prometheus(capsys.readouterr().out) > 0

            assert dstat.main(["dump", root]) == 0
            assert "counters:" in capsys.readouterr().out

            old = os.path.join(d, "old.json")
            with open(old, "w") as fh:
                json.dump(snap, fh)
            log.prov_query("B", "A", np.array([[5, 5]]))
            log.save()
            assert dstat.main(["diff", old, root, "--json"]) == 0
            delta = json.loads(capsys.readouterr().out)
            assert delta["counters"].get("queries{path=planned}", 0) >= 1
        finally:
            log.close()
        assert dstat.main(["dump", os.path.join(d, "missing")]) == 2


def test_health_reports_flags_and_fsck():
    with tempfile.TemporaryDirectory() as d:
        log = _store_with_traffic(d)
        try:
            rep = log.health()
            assert rep["ok"] is True and rep["flags"] == []
            assert rep["fsck"] is not None
            log.metrics.inc("wal_replayed", 5)
            rep = log.health(run_fsck=False)
            assert [f["flag"] for f in rep["flags"]] == ["wal-replayed"]
            assert rep["ok"] is True  # warnings don't fail health
        finally:
            log.close()


# --------------------------------------------------------------------------- #
# bit-identity tracing on/off (DSLog + ShardedDSLog N in {1, 4})
# --------------------------------------------------------------------------- #
@pytest.mark.parametrize("make", [
    lambda: DSLog(),
    lambda: ShardedDSLog(n_shards=1),
    lambda: ShardedDSLog(n_shards=4),
], ids=["dslog", "sharded1", "sharded4"])
def test_bit_identical_results_tracing_on_off(make, race_detector):
    plain, traced = make(), make()
    names = _build_random_dag([plain, traced], n_ops=8, seed=23)
    cells = np.array([[2, 3], [7, 0], [4, 4]])
    for src, dst in [(names[-1], names[0]), (names[0], names[-1])]:
        a = plain.prov_query(src, dst, cells)
        b, tr = traced.prov_query(src, dst, cells, trace=True)
        assert tr.root.duration > 0 and "hop" in tr.kinds()
        assert a.shape == b.shape
        assert a.lo.tobytes() == b.lo.tobytes()
        assert a.hi.tobytes() == b.hi.tobytes()


# --------------------------------------------------------------------------- #
# tracing-off instrument cost
# --------------------------------------------------------------------------- #
def test_tracing_off_instrument_cost_bounded():
    """The per-query telemetry tax when tracing is off stays sub-10us/op.

    The off-path adds: one ``_active_trace is None`` check per site, a few
    null-context allocations, and one ``observe`` + ``inc`` pair per query.
    Bound each primitive at 50us/op average over 20k calls — two orders of
    magnitude above their real cost, so the test only fails on a genuine
    regression (e.g. a span allocated while tracing is off).
    """
    n = 20_000
    reg = MetricsRegistry("bench")
    t0 = time.perf_counter()
    for _ in range(n):
        reg.inc("queries", path="planned")
    inc_us = (time.perf_counter() - t0) / n * 1e6
    t0 = time.perf_counter()
    for _ in range(n):
        reg.observe("query_seconds", 1e-4, path="planned", engine="batched")
    obs_us = (time.perf_counter() - t0) / n * 1e6
    t0 = time.perf_counter()
    for _ in range(n):
        with maybe_span(None, "plan", kind="plan") as sp:
            sp.attrs["x"] = 1
    null_us = (time.perf_counter() - t0) / n * 1e6
    assert inc_us < 50, f"registry.inc too slow: {inc_us:.2f}us/op"
    assert obs_us < 50, f"registry.observe too slow: {obs_us:.2f}us/op"
    assert null_us < 50, f"null span too slow: {null_us:.2f}us/op"
