"""fsck: property test over random crash stores + one test per corruption
class, plus the vacuum/orphan closure regression (fsck and ``_vacuum_dir``
must agree on what a manifest references).

Corruption classes demonstrated (ISSUE 6 asks for >= 5): torn WAL tail,
WAL crc flip, WAL header LSN skew, orphaned blob, dangling blob handle,
undecodable blob, shard-map mismatch, unparseable manifest, DAG cycle,
stale writer lease.
"""

import glob
import json
import os
import socket
import struct
import subprocess
import sys
import tempfile

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.capture import identity_lineage, roll_lineage
from repro.core.catalog import DSLog
from repro.core.shard import ShardedDSLog
from repro.tools import fsck
from repro.tools.mkstore import build_store

from test_crash_recovery import _HEADER, _ingest_random_dag

_MAGIC_LEN = 7  # b"DSWAL1\n"


def _edit_json(path):
    with open(path) as f:
        return json.load(f)


def _write_json(path, meta):
    with open(path, "w") as f:
        json.dump(meta, f)


def _flip_byte(path, offset):
    with open(path, "r+b") as f:
        f.seek(offset)
        b = f.read(1)
        f.seek(offset)
        f.write(bytes([b[0] ^ 0xFF]))


def _live_wal_store(root, n_ops=6, seed=11):
    """Sharded store closed without checkpoint: WALs still carry records."""
    log = ShardedDSLog.open(root, 4)
    entries = _ingest_random_dag(log, n_ops, seed)
    log.commit()
    log.close(checkpoint=False)
    wals = [
        p
        for p in glob.glob(os.path.join(root, "**", "wal.log"), recursive=True)
        if os.path.getsize(p) > _HEADER
    ]
    assert wals, "recipe must leave record-bearing WALs behind"
    return entries, wals


# --------------------------------------------------------------------------- #
# clean stores pass
# --------------------------------------------------------------------------- #
def test_checkpointed_store_is_spotless(tmp_path):
    root = str(tmp_path / "s")
    build_store(root, n_shards=4, n_ops=10, seed=3)
    report = fsck.fsck_store(root)
    assert report.ok
    assert report.findings == [], [str(f) for f in report.findings]
    assert report.checked["shards"] == 4
    assert report.checked["entries"] > 0
    assert report.checked["blobs"] > 0


def test_single_dslog_store_is_spotless(tmp_path):
    root = str(tmp_path / "s")
    log = DSLog.open(root)
    log.add_lineage("a", "b", identity_lineage((8, 8)))
    log.add_lineage("b", "c", roll_lineage((8, 8), 2, 0))
    log.save()
    log.close()
    report = fsck.fsck_store(root)
    assert report.ok and report.findings == []


@settings(max_examples=8, deadline=None)
@given(
    n_ops=st.integers(4, 8),
    seed=st.integers(0, 10_000),
    kind=st.sampled_from(["dslog", "shard4"]),
    data=st.data(),
)
def test_random_crash_store_passes_fsck(n_ops, seed, kind, data):
    """Any store a random op/checkpoint/crash sequence can produce has no
    fsck *errors* — a crash may leave warn-level debris (torn tails,
    orphans) but never an inconsistency recovery cannot absorb."""
    with tempfile.TemporaryDirectory() as d:
        root = os.path.join(d, "s")
        if kind == "dslog":
            log = DSLog.open(root)
        else:
            log = ShardedDSLog.open(root, 4)
        _ingest_random_dag(log, n_ops, seed)
        if data.draw(st.integers(0, 2), label="ckpt") == 1:
            log.checkpoint()
            _ingest_random_dag(log, 3, seed + 1)
        log.commit()
        log.close(checkpoint=False)

        wals = [
            p
            for p in glob.glob(os.path.join(root, "**", "wal.log"), recursive=True)
            if os.path.getsize(p) > _HEADER
        ]
        if wals and data.draw(st.integers(0, 1), label="crash"):
            victim = wals[data.draw(st.integers(0, len(wals) - 1), label="wal")]
            size = os.path.getsize(victim)
            cut = data.draw(st.integers(_HEADER, size - 1), label="cut")
            with open(victim, "r+b") as f:
                f.truncate(cut)

        report = fsck.fsck_store(root)
        assert report.ok, [str(f) for f in report.errors]
        for f in report.findings:  # debris is at most warn-level
            assert f.severity in ("warn", "info"), str(f)

        # and after recovery + checkpoint the store is spotless again
        if kind == "dslog":
            with DSLog.open(root):
                pass
        else:
            with ShardedDSLog.open(root, 4):
                pass
        after = fsck.fsck_store(root)
        assert after.ok and after.findings == [], [str(f) for f in after.findings]


# --------------------------------------------------------------------------- #
# corruption classes: each flags its category
# --------------------------------------------------------------------------- #
def test_torn_wal_tail_is_a_warning(tmp_path):
    root = str(tmp_path / "s")
    _, wals = _live_wal_store(root)
    with open(wals[0], "r+b") as f:
        f.truncate(os.path.getsize(wals[0]) - 3)
    report = fsck.fsck_store(root)
    assert report.ok  # recovery truncates the tail: no durable loss
    assert "wal-torn-tail" in report.categories()


def test_wal_crc_flip_is_an_error(tmp_path):
    root = str(tmp_path / "s")
    _, wals = _live_wal_store(root)
    victim = wals[0]
    with open(victim, "rb") as f:
        data = f.read()
    # flip one payload byte of the first complete record (headers would
    # read as a torn tail instead — a different, weaker diagnosis)
    length, _crc = struct.unpack_from("<II", data, _HEADER)
    assert _HEADER + 8 + length <= len(data)
    _flip_byte(victim, _HEADER + 8 + length // 2)
    report = fsck.fsck_store(root)
    assert not report.ok
    assert "wal-crc" in {f.category for f in report.errors}


def test_wal_lsn_skew_is_an_error(tmp_path):
    root = str(tmp_path / "s")
    build_store(root, n_shards=2, n_ops=6, seed=5)
    meta = _edit_json(os.path.join(root, "catalog.json"))
    wal = os.path.join(root, "wal.log")
    with open(wal, "r+b") as f:
        f.seek(_MAGIC_LEN)
        f.write(struct.pack("<Q", int(meta["wal_lsn"]) + 1000))
    report = fsck.fsck_store(root)
    assert not report.ok
    assert "wal-lsn" in {f.category for f in report.errors}


def test_orphan_blob_is_a_warning(tmp_path):
    root = str(tmp_path / "s")
    build_store(root, n_shards=2, n_ops=6, seed=5)
    stray = os.path.join(root, "shard_00", "lineage_9999.prvc")
    with open(stray, "wb") as f:
        f.write(b"\x00" * 32)
    report = fsck.fsck_store(root)
    assert report.ok  # unreferenced garbage loses nothing
    assert "orphan-blob" in report.categories()
    assert any(f.path.endswith("lineage_9999.prvc") for f in report.warnings)


def test_dangling_handle_is_an_error(tmp_path):
    root = str(tmp_path / "s")
    build_store(root, n_shards=2, n_ops=6, seed=5)
    victim = None
    for k in range(2):
        sub = os.path.join(root, f"shard_{k:02d}")
        meta = _edit_json(os.path.join(sub, "catalog.json"))
        if meta.get("lineage"):
            victim = os.path.join(sub, meta["lineage"][0]["file"])
            break
    assert victim is not None and os.path.exists(victim)
    os.unlink(victim)
    report = fsck.fsck_store(root)
    assert not report.ok
    assert "dangling-handle" in {f.category for f in report.errors}


def test_blob_byte_flip_is_an_error(tmp_path):
    root = str(tmp_path / "s")
    build_store(root, n_shards=2, n_ops=6, seed=5)
    victim = None
    for k in range(2):
        sub = os.path.join(root, f"shard_{k:02d}")
        meta = _edit_json(os.path.join(sub, "catalog.json"))
        if meta.get("lineage"):
            victim = os.path.join(sub, meta["lineage"][0]["file"])
            break
    assert victim is not None
    _flip_byte(victim, os.path.getsize(victim) // 2)
    report = fsck.fsck_store(root)
    assert not report.ok
    assert {"blob-decode", "blob-invariant"} & {f.category for f in report.errors}


def test_shard_map_mismatch_is_an_error(tmp_path):
    root = str(tmp_path / "s")
    build_store(root, n_shards=4, n_ops=8, seed=5)
    path = os.path.join(root, "catalog.json")
    meta = _edit_json(path)
    assert meta["edges"], "store must have edges"
    src, dst, lid, shard = meta["edges"][0]
    meta["edges"][0] = [src, dst, lid, (int(shard) + 1) % 4]
    _write_json(path, meta)
    report = fsck.fsck_store(root)
    assert not report.ok
    assert "shard-map" in {f.category for f in report.errors}


def test_unparseable_manifest_is_an_error(tmp_path):
    root = str(tmp_path / "s")
    build_store(root, n_shards=2, n_ops=6, seed=5)
    _flip_byte(os.path.join(root, "catalog.json"), 0)
    report = fsck.fsck_store(root)
    assert not report.ok
    assert "manifest-parse" in {f.category for f in report.errors}


def test_dag_cycle_is_an_error(tmp_path):
    root = str(tmp_path / "s")
    log = DSLog.open(root)
    log.add_lineage("a", "b", identity_lineage((8, 8)))
    log.add_lineage("b", "c", roll_lineage((8, 8), 2, 0))
    log.save()
    log.close()
    path = os.path.join(root, "catalog.json")
    meta = _edit_json(path)
    back = dict(meta["lineage"][0])  # reuse its blobs: only the edge is fake
    back["id"] = 999
    back["src"], back["dst"] = "c", "a"
    meta["lineage"].append(back)
    _write_json(path, meta)
    report = fsck.fsck_store(root)
    assert not report.ok
    assert "dag-cycle" in {f.category for f in report.errors}


def test_stale_lease_is_a_warning(tmp_path):
    root = str(tmp_path / "s")
    build_store(root, n_shards=2, n_ops=6, seed=5)
    proc = subprocess.run([sys.executable, "-c", "import os; print(os.getpid())"],
                          capture_output=True, text=True)
    dead_pid = int(proc.stdout)
    with open(os.path.join(root, "writer.lock"), "w") as f:
        json.dump({"pid": dead_pid, "host": socket.gethostname(), "token": "x"}, f)
    report = fsck.fsck_store(root)
    assert report.ok  # the next open steals it: informational only
    assert "stale-lease" in report.categories()


def test_cli_exit_codes(tmp_path):
    root = str(tmp_path / "s")
    build_store(root, n_shards=2, n_ops=6, seed=5)
    src = os.path.join(os.path.dirname(__file__), "..", "src")
    env = dict(os.environ, PYTHONPATH=os.path.abspath(src))
    run = lambda *a: subprocess.run(  # noqa: E731
        [sys.executable, "-m", "repro.tools.fsck", *a],
        capture_output=True, text=True, env=env,
    )
    clean = run(root)
    assert clean.returncode == 0 and "clean" in clean.stdout
    _flip_byte(os.path.join(root, "catalog.json"), 0)
    corrupt = run(root)
    assert corrupt.returncode == 1 and "CORRUPT" in corrupt.stdout
    assert run(str(tmp_path / "nonexistent")).returncode == 2
    empty = tmp_path / "empty"
    empty.mkdir()
    assert run(str(empty)).returncode == 2


# --------------------------------------------------------------------------- #
# satellite 6: fsck's orphan closure == _vacuum_dir's closure
# --------------------------------------------------------------------------- #
def test_vacuumed_store_is_fsck_clean(tmp_path):
    """Dropping lineage leaves orphans fsck flags; compact() (which
    vacuums with the shared closure helper) must silence every one."""
    root = str(tmp_path / "s")
    log = ShardedDSLog.open(root, 4)
    entries = _ingest_random_dag(log, 8, seed=13)
    log.save()
    for lid, *_ in entries[1:4]:
        log.drop_lineage(lid)
    log.save()
    log.close()

    before = fsck.fsck_store(root)
    assert before.ok
    assert "orphan-blob" in before.categories()

    with ShardedDSLog.open(root, 4) as log:
        log.compact()

    after = fsck.fsck_store(root)
    assert after.ok and after.findings == [], [str(f) for f in after.findings]


def test_fsck_never_mutates(tmp_path):
    root = str(tmp_path / "s")
    _, wals = _live_wal_store(root)
    with open(wals[0], "r+b") as f:
        f.truncate(os.path.getsize(wals[0]) - 3)  # leave debris behind

    def snapshot():
        out = {}
        for dirpath, _, files in os.walk(root):
            for fn in files:
                p = os.path.join(dirpath, fn)
                with open(p, "rb") as f:
                    out[os.path.relpath(p, root)] = f.read()
        return out

    before = snapshot()
    fsck.fsck_store(root)
    assert snapshot() == before
