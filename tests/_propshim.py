"""Minimal seeded-random stand-in for ``hypothesis``.

When the real ``hypothesis`` package is unavailable, ``conftest.py`` installs
this module as ``sys.modules["hypothesis"]`` (and ``hypothesis.strategies``)
*before* test modules import it, so property tests still execute — with
deterministic seeding and a reduced example count instead of full shrinking
search.  Only the API surface this repo's tests use is implemented:

* ``given(**kwargs)`` / ``settings(max_examples=..., deadline=...)``
* ``strategies.integers(lo, hi)`` (inclusive, like hypothesis)
* ``strategies.sampled_from(seq)``
* ``strategies.data()`` with ``data.draw(strategy)``

Example counts are capped at ``PROPSHIM_MAX_EXAMPLES`` (default 15): the
point of the fallback is coverage of the property bodies, not exhaustive
search — install ``hypothesis`` for that.
"""

from __future__ import annotations

import functools
import inspect
import os
import sys
import types
import zlib

import numpy as np

_EXAMPLE_CAP = int(os.environ.get("PROPSHIM_MAX_EXAMPLES", "15"))


class _Strategy:
    def __init__(self, sample):
        self._sample = sample

    def sample(self, rng: np.random.Generator):
        return self._sample(rng)


def integers(min_value: int, max_value: int) -> _Strategy:
    return _Strategy(lambda rng: int(rng.integers(min_value, max_value + 1)))


def sampled_from(seq) -> _Strategy:
    seq = list(seq)
    return _Strategy(lambda rng: seq[int(rng.integers(len(seq)))])


class _DataObject:
    def __init__(self, rng: np.random.Generator):
        self._rng = rng

    def draw(self, strategy: _Strategy, label: str | None = None):
        return strategy.sample(self._rng)


class _DataStrategy(_Strategy):
    def __init__(self):
        super().__init__(lambda rng: _DataObject(rng))


def data() -> _DataStrategy:
    return _DataStrategy()


def given(*args, **kwargs):
    if args:
        raise NotImplementedError("propshim only supports given(**kwargs)")

    def decorate(fn):
        @functools.wraps(fn)
        def wrapper():
            n = min(getattr(wrapper, "_propshim_max_examples", _EXAMPLE_CAP),
                    _EXAMPLE_CAP)
            base = zlib.crc32(fn.__qualname__.encode())
            for i in range(n):
                rng = np.random.default_rng((base, i))
                drawn = {k: s.sample(rng) for k, s in kwargs.items()}
                try:
                    fn(**drawn)
                except Exception:
                    print(
                        f"propshim falsifying example ({fn.__qualname__}, "
                        f"example {i}): {drawn}",
                        file=sys.stderr,
                    )
                    raise

        # hide the strategy-bound parameters from pytest's fixture resolution
        wrapper.__signature__ = inspect.Signature()
        del wrapper.__wrapped__
        return wrapper

    return decorate


def settings(max_examples: int = _EXAMPLE_CAP, deadline=None, **_ignored):
    def decorate(fn):
        fn._propshim_max_examples = max_examples
        return fn

    return decorate


def install() -> None:
    """Register this module as ``hypothesis`` (+ ``hypothesis.strategies``)."""
    this = sys.modules[__name__]
    hyp = types.ModuleType("hypothesis")
    hyp.given = given
    hyp.settings = settings
    hyp.__propshim__ = True
    st = types.ModuleType("hypothesis.strategies")
    st.integers = integers
    st.sampled_from = sampled_from
    st.data = data
    hyp.strategies = st
    hyp.__propshim_source__ = this
    sys.modules["hypothesis"] = hyp
    sys.modules["hypothesis.strategies"] = st
