"""Persistence v2: lazy handles, dirty-tracked saves, reuse-state round-trip."""

import glob
import json
import os
import tempfile

import numpy as np
import pytest

from repro.core.capture import identity_lineage, reduce_lineage
from repro.core.catalog import DSLog


def _three_chains(root):
    """Three independent 1-hop chains so a query can touch a strict subset."""
    log = DSLog(root=root, store_forward=True)
    log.add_lineage("A", "B", identity_lineage((6, 3)))
    log.add_lineage("C", "D", reduce_lineage((6, 3), 1))
    log.add_lineage("E", "F", identity_lineage((5,)))
    log.save()
    return log


def test_lazy_reload_deserializes_only_touched_tables():
    with tempfile.TemporaryDirectory() as d:
        _three_chains(d)
        log2 = DSLog.load(d)
        assert log2.io_stats["tables_loaded"] == 0
        assert not any(e.backward_loaded or e.forward_loaded for e in log2.lineage.values())
        # graph is rebuilt without touching any blob
        assert log2.graph.has_path("A", "B") and not log2.graph.has_path("A", "D")

        res = log2.prov_query("B", "A", np.array([[4, 1]]))
        assert res.cell_set() == {(4, 1)}
        # exactly one materialization of one entry was deserialized
        assert log2.io_stats["tables_loaded"] == 1
        touched = [e for e in log2.lineage.values() if e.backward_loaded or e.forward_loaded]
        assert len(touched) == 1 and touched[0].src == "A"
        untouched = [e for e in log2.lineage.values() if e.src != "A"]
        assert all(not e.backward_loaded and not e.forward_loaded for e in untouched)


def test_manifest_records_rows_for_costing_without_io():
    with tempfile.TemporaryDirectory() as d:
        log = _three_chains(d)
        want = {e.lineage_id: e.backward.n_rows for e in log.lineage.values()}
        log2 = DSLog.load(d)
        got = {e.lineage_id: e.backward_rows for e in log2.lineage.values()}
        assert got == want
        assert log2.io_stats["tables_loaded"] == 0  # row counts came from JSON
        # planning a query is free of blob I/O too
        log2.planner.plan("B", ["A"])
        assert log2.io_stats["tables_loaded"] == 0


def test_dirty_save_writes_only_new_entries():
    with tempfile.TemporaryDirectory() as d:
        log = _three_chains(d)
        first_written = log.io_stats["tables_written"]
        assert first_written == 6  # 3 entries x (backward + forward)
        log.save()  # nothing dirty -> no table rewrites
        assert log.io_stats["tables_written"] == first_written

        log.add_lineage("F", "G", identity_lineage((5,)))
        log.save()
        assert log.io_stats["tables_written"] == first_written + 2

        # a reloaded catalog extends incrementally without deserializing or
        # rewriting the clean (still-lazy) entries
        log2 = DSLog.load(d)
        log2.add_lineage("G", "H", identity_lineage((5,)))
        log2.save()
        assert log2.io_stats["tables_written"] == 2
        assert log2.io_stats["tables_loaded"] == 0
        log3 = DSLog.load(d)
        assert len(log3.lineage) == 5
        res = log3.prov_query(["H", "G", "F", "E"], np.array([[2]]))
        assert res.cell_set() == {(2,)}


def test_ops_round_trip():
    with tempfile.TemporaryDirectory() as d:
        log = DSLog(root=d)
        log.define_array("x", (4, 3))
        log.define_array("y", (4,))
        log.register_operation(
            "rowsum", ["x"], ["y"],
            capture=lambda: {(0, 0): reduce_lineage((4, 3), 1)},
            op_args={"axis": 1},
        )
        log.save()
        log2 = DSLog.load(d)
        assert len(log2.ops) == 1
        op = log2.ops[0]
        assert op.op_name == "rowsum"
        assert op.in_arrs == ("x",) and op.out_arrs == ("y",)
        assert op.op_args == {"axis": 1}
        assert op.lineage_ids == [0] and op.reused is None


def test_reload_keeps_confirmed_gen_sig_mapping():
    """Regression (ISSUE 2): load() used to drop ops + predictor state, so a
    persisted catalog silently restarted reuse from scratch."""
    with tempfile.TemporaryDirectory() as d:
        log = DSLog(root=d, reuse_m=1)
        for i, shape in enumerate([(4, 2), (4, 2), (9, 5)]):
            log.define_array(f"x{i}", shape)
            log.define_array(f"y{i}", shape)
            log.register_operation(
                "neg", [f"x{i}"], [f"y{i}"],
                capture=lambda s=shape: {(0, 0): identity_lineage(s)},
            )
        from repro.core.reuse import sig_key_gen

        assert log.predictor.status(sig_key_gen("neg", None)) == "confirmed"
        log.save()

        log2 = DSLog.load(d)
        assert log2.predictor.status(sig_key_gen("neg", None)) == "confirmed"
        # a brand-new shape must bypass capture entirely (capture=None works)
        log2.define_array("x9", (3, 7))
        log2.define_array("y9", (3, 7))
        rec = log2.register_operation("neg", ["x9"], ["y9"], capture=None)
        assert rec.reused == "gen"
        res = log2.prov_query("y9", "x9", np.array([[2, 6]]))
        assert res.cell_set() == {(2, 6)}


def test_reload_keeps_confirmed_dim_sig_mapping():
    with tempfile.TemporaryDirectory() as d:
        log = DSLog(root=d, reuse_m=1)
        for i in range(2):
            log.define_array(f"a{i}", (6, 4))
            log.define_array(f"b{i}", (6, 4))
            log.register_operation(
                "exp", [f"a{i}"], [f"b{i}"],
                capture=lambda: {(0, 0): identity_lineage((6, 4))},
            )
        log.save()
        log2 = DSLog.load(d)
        calls = {"n": 0}

        def capture():
            calls["n"] += 1
            return {(0, 0): identity_lineage((6, 4))}

        log2.define_array("a9", (6, 4))
        log2.define_array("b9", (6, 4))
        rec = log2.register_operation("exp", ["a9"], ["b9"], capture=capture)
        assert rec.reused == "dim"
        assert calls["n"] == 0  # capture bypassed after reload


def test_predictor_state_not_rewritten_when_clean():
    with tempfile.TemporaryDirectory() as d:
        log = DSLog(root=d, reuse_m=1)
        log.define_array("a", (4,))
        log.define_array("b", (4,))
        log.register_operation(
            "neg", ["a"], ["b"], capture=lambda: {(0, 0): identity_lineage((4,))}
        )
        log.save()
        sigs = sorted(glob.glob(os.path.join(d, "sig_*.prvc")))
        assert sigs  # the tentative signatures persisted their tables
        mtimes = [os.path.getmtime(p) for p in sigs]
        log.add_lineage("b", "c", identity_lineage((4,)))  # no predictor change
        log.save()
        assert [os.path.getmtime(p) for p in sigs] == mtimes


def test_predictor_dirty_tracking_is_per_signature():
    """An observation touching one signature must not rewrite the sig blobs
    of other, unrelated signatures (per-signature dirty tracking)."""
    with tempfile.TemporaryDirectory() as d:
        log = DSLog(root=d, reuse_m=2)
        for i, op in enumerate(["neg", "exp"]):
            log.define_array(f"a{i}", (4,))
            log.define_array(f"b{i}", (4,))
            log.register_operation(
                op, [f"a{i}"], [f"b{i}"],
                capture=lambda: {(0, 0): identity_lineage((4,))},
            )
        log.save()
        chunk = log._predictor_chunk
        files = {
            rec["key"]: sorted(rec["tables"].values()) for rec in chunk["sigs"]
        }
        # resolve blob paths per op from the manifest records themselves
        neg_keys = [k for k in files if "neg" in k]
        exp_keys = [k for k in files if "exp" in k]
        assert neg_keys and exp_keys
        exp_blobs = [os.path.join(d, fn) for k in exp_keys for fn in files[k]]
        neg_blobs = [os.path.join(d, fn) for k in neg_keys for fn in files[k]]
        exp_mtimes = [os.path.getmtime(p) for p in exp_blobs]
        neg_mtimes = [os.path.getmtime(p) for p in neg_blobs]

        # second matching neg observation mutates only the neg signatures
        log.define_array("a9", (4,))
        log.define_array("b9", (4,))
        log.register_operation(
            "neg", ["a9"], ["b9"],
            capture=lambda: {(0, 0): identity_lineage((4,))},
        )
        assert log.predictor.dirty
        import time

        time.sleep(0.01)  # mtime resolution guard
        log.save()
        assert not log.predictor.dirty
        assert [os.path.getmtime(p) for p in exp_blobs] == exp_mtimes
        assert [os.path.getmtime(p) for p in neg_blobs] != neg_mtimes


def test_v1_manifest_still_loads():
    """Manifests written before the graph/planner rework (no version, ops,
    predictor, or row counts) keep loading — just without reuse state."""
    with tempfile.TemporaryDirectory() as d:
        _three_chains(d)
        path = os.path.join(d, "catalog.json")
        with open(path) as f:
            meta = json.load(f)
        for key in ("version", "ops", "predictor"):
            meta.pop(key, None)
        for rec in meta["lineage"]:
            rec.pop("rows", None)
            rec.pop("fwd_rows", None)
        with open(path, "w") as f:
            json.dump(meta, f)
        log = DSLog.load(d)
        assert log.ops == []
        res = log.prov_query(["B", "A"], np.array([[4, 1]]))
        assert res.cell_set() == {(4, 1)}
        # rows were absent from the manifest: reading them forces the load
        assert all(isinstance(e.backward_rows, int) for e in log.lineage.values())


def test_save_without_root_raises():
    with pytest.raises(ValueError):
        DSLog().save()
    with pytest.raises(ValueError):
        DSLog().compact()


def test_compact_vacuums_dropped_and_stray_blobs():
    """GC for persistence v2: dropped entries' blobs (and stale sig tables)
    are deleted by compact(), never by save()."""
    with tempfile.TemporaryDirectory() as d:
        log = DSLog(root=d)
        e = log.add_lineage("a", "b", identity_lineage((8, 8)))
        log.add_lineage("b", "c", identity_lineage((8, 8)))
        log.save()
        dropped_blob = os.path.join(d, f"lineage_{e.lineage_id}.prvc")
        assert os.path.exists(dropped_blob)
        log.drop_lineage(e.lineage_id)
        log.save()  # dirty-tracked save leaves the orphan behind
        assert os.path.exists(dropped_blob)
        stray = os.path.join(d, "sig_cafecafe00_0-0.prvc")
        with open(stray, "wb") as f:
            f.write(b"stale predictor table")
        stats = log.compact()
        assert stats["files_removed"] >= 3  # bwd + fwd + stray sig
        assert stats["bytes_reclaimed"] > 0
        assert not os.path.exists(dropped_blob)
        assert not os.path.exists(stray)
        # referenced blobs survived and the catalog still answers
        re = DSLog.load(d)
        assert set(re.lineage) == {1}
        assert re.prov_query("c", "b", np.array([[1, 2]])).cell_set() == {(1, 2)}
        # an unrelated user file is never touched
        keep = os.path.join(d, "notes.txt")
        with open(keep, "w") as f:
            f.write("mine")
        log.compact()
        assert os.path.exists(keep)


def test_version_helper_for_in_place_ops():
    """DSLog.version() mints acc@k names so accumulator updates don't trip
    the DAG's self-lineage rejection; counters survive reload."""
    with tempfile.TemporaryDirectory() as d:
        log = DSLog(root=d)
        log.define_array("acc", (4,))
        from repro.core.graph import CycleError

        with pytest.raises(CycleError):
            log.add_lineage("acc", "acc", identity_lineage((4,)))
        prev = log.latest_version("acc")
        assert prev == "acc"
        for k in range(1, 4):
            cur = log.version("acc")
            assert cur == f"acc@{k}"
            assert log.arrays[cur].shape == (4,)  # shape inherited
            log.add_lineage(prev, cur, identity_lineage((4,)))
            prev = cur
        assert log.latest_version("acc") == "acc@3"
        res = log.prov_query("acc@3", "acc", np.array([[2]]))
        assert res.cell_set() == {(2,)}
        log.save()
        re = DSLog.load(d)
        assert re.latest_version("acc") == "acc@3"
        assert re.version("acc") == "acc@4"
        # versioning a never-declared base mints names without a shape
        assert re.version("fresh", shape=(3, 3)) == "fresh@1"
        assert re.arrays["fresh@1"].shape == (3, 3)


def test_hop_feedback_measured_selectivity_round_trips():
    """Execution records true per-hop pair counts; a reloaded catalog
    replans from the measured selectivities, not the closed-form model."""
    with tempfile.TemporaryDirectory() as d:
        log = DSLog(root=d, store_forward=False)
        log.add_lineage("a", "b", identity_lineage((8, 8)))
        log.add_lineage("b", "c", reduce_lineage((8, 8), 1))
        assert log.hop_measurement(0, "backward", "key") is None
        log.prov_query("c", "a", np.array([[3]]))
        m0 = log.hop_measurement(0, "backward", "key")
        m1 = log.hop_measurement(1, "backward", "key")
        assert m0 is not None and m1 is not None
        log.save()
        re = DSLog.load(d)
        assert re.hop_measurement(0, "backward", "key") == m0
        assert re.hop_measurement(1, "backward", "key") == m1
        # replanning prefers the measurement for hops beyond the frontier:
        # the deep hop's estimate equals measured pairs-per-box exactly
        plan = re.planner.plan("c", ["a"])
        deep = plan.steps["a"][0].choices[0]
        assert deep.est_pairs == pytest.approx(max(1.0, m0 * plan.est_boxes["b"]))
