"""racecheck: instrumented locks, rank checks, cycles, guarded state.

These tests drive the detector's primitives directly (with the env var set
via monkeypatch) — the end-to-end wiring is exercised by the autouse
fixtures in test_wal / test_crash_recovery / test_accel / test_shard.
"""

import threading

import pytest

from repro.tools import racecheck
from repro.tools.racecheck import GuardedDict, GuardedList, InstrumentedLock


@pytest.fixture(autouse=True)
def _clean_registry(monkeypatch):
    monkeypatch.setenv("DSLOG_RACE_DETECT", "1")
    racecheck.reset()
    yield
    racecheck.reset()


# --------------------------------------------------------------------------- #
# lock ordering
# --------------------------------------------------------------------------- #
def test_declared_order_is_clean():
    outer = InstrumentedLock("commit._flush_mutex")
    inner = InstrumentedLock("wal._lock")
    with outer:
        with inner:
            pass
    assert racecheck.findings() == []


def test_rank_violation_detected():
    wal = InstrumentedLock("wal._lock")          # rank 50
    commit = InstrumentedLock("commit._lock")    # rank 40
    with wal:
        with commit:  # inner rank below outer: declared order violated
            pass
    findings = racecheck.findings()
    assert any("lock-order" in f and "commit._lock" in f for f in findings)


def test_same_rank_different_instance_is_violation():
    a = InstrumentedLock("table._lock")
    b = InstrumentedLock("table._lock")
    with a:
        with b:
            pass
    assert any("lock-order" in f for f in racecheck.findings())


def test_rlock_reentry_is_not_a_violation():
    lock = InstrumentedLock("catalog._stats_lock", reentrant=True)
    with lock:
        with lock:
            pass
    assert racecheck.findings() == []


def test_cross_thread_cycle_detected():
    """Inverted acquisition orders on different threads form a graph cycle.

    The threads run one after the other — the detector's value is exactly
    that it flags the *potential* deadlock without needing the unlucky
    interleaving that would actually wedge both threads.
    """
    a = InstrumentedLock("t.A")  # unranked: only the cycle check sees these
    b = InstrumentedLock("t.B")

    def one():
        with a:
            with b:
                pass

    def two():
        with b:
            with a:
                pass

    t1 = threading.Thread(target=one)
    t1.start()
    t1.join()
    t2 = threading.Thread(target=two)
    t2.start()
    t2.join()
    assert any("lock-cycle" in f for f in racecheck.findings())


def test_edges_recorded_per_acquisition():
    outer = InstrumentedLock("commit._flush_mutex")
    inner = InstrumentedLock("wal._lock")
    with outer:
        with inner:
            pass
    assert ("commit._flush_mutex", "wal._lock") in racecheck.edges()


# --------------------------------------------------------------------------- #
# guarded shared state
# --------------------------------------------------------------------------- #
def test_guarded_dict_flags_unguarded_mutation():
    guard = InstrumentedLock("catalog._stats_lock", reentrant=True)
    stats = GuardedDict({"n": 0}, guard, "DSLog.io_stats")
    stats["n"] = 1  # no lock held
    assert any("unguarded-mutation" in f for f in racecheck.findings())


def test_guarded_dict_clean_under_lock():
    guard = InstrumentedLock("catalog._stats_lock", reentrant=True)
    stats = GuardedDict({"n": 0}, guard, "DSLog.io_stats")
    with guard:
        stats["n"] = 1
        stats.update(m=2)
        stats.setdefault("k", [])
        del stats["m"]
    assert racecheck.findings() == []
    assert stats == {"n": 1, "k": []}


def test_guarded_dict_reads_unchecked():
    guard = InstrumentedLock("catalog._stats_lock", reentrant=True)
    stats = GuardedDict({"n": 3}, guard, "DSLog.io_stats")
    assert stats["n"] == 3
    assert stats.get("missing") is None
    assert list(stats.items()) == [("n", 3)]
    assert racecheck.findings() == []


def test_guarded_list_flags_unguarded_mutation():
    guard = InstrumentedLock("shard._shard_load_lock")
    shards = GuardedList([None, None], guard, "ShardedDSLog._shards")
    shards[0] = object()
    assert any("unguarded-mutation" in f for f in racecheck.findings())
    racecheck.reset()
    with guard:
        shards[1] = object()
    assert racecheck.findings() == []


def test_detection_stops_when_env_cleared(monkeypatch):
    guard = InstrumentedLock("catalog._stats_lock", reentrant=True)
    stats = GuardedDict({}, guard, "DSLog.io_stats")
    monkeypatch.delenv("DSLOG_RACE_DETECT")
    stats["n"] = 1  # detector off: recording suspended
    assert racecheck.findings() == []


# --------------------------------------------------------------------------- #
# _locks factory wiring
# --------------------------------------------------------------------------- #
def test_locks_factory_returns_plain_locks_when_disabled(monkeypatch):
    monkeypatch.delenv("DSLOG_RACE_DETECT")
    from repro.core import _locks

    assert not isinstance(_locks.new_lock("wal._lock"), InstrumentedLock)
    assert isinstance(_locks.guard_mapping({"a": 1}, None, "x"), dict)
    assert not isinstance(_locks.guard_mapping({"a": 1}, None, "x"), GuardedDict)


def test_locks_factory_returns_instrumented_when_enabled():
    from repro.core import _locks

    lock = _locks.new_lock("wal._lock")
    assert isinstance(lock, InstrumentedLock) and not lock.reentrant
    rlock = _locks.new_rlock("catalog._stats_lock")
    assert isinstance(rlock, InstrumentedLock) and rlock.reentrant
    stats = _locks.guard_mapping({"a": 1}, rlock, "x")
    assert isinstance(stats, GuardedDict)
    seq = _locks.guard_sequence([None], lock, "y")
    assert isinstance(seq, GuardedList)


def test_store_end_to_end_clean_under_detector(tmp_path):
    """A real store exercising WAL + commit + stats stays finding-free."""
    import numpy as np

    from repro.core.capture import identity_lineage, roll_lineage
    from repro.core.catalog import DSLog

    log = DSLog.open(str(tmp_path / "s"))
    log.add_lineage("a", "b", identity_lineage((8, 8)))
    log.add_lineage("b", "c", roll_lineage((8, 8), 2, 0))
    log.prov_query("a", "c", np.array([[1, 2]]))
    log.save()
    log.close()
    assert racecheck.findings() == []
