"""Distribution layer: sharding resolution, multi-device collectives and
elastic restore — the multi-device parts run in a subprocess with 8
placeholder CPU devices (the main test process must keep 1 device)."""

import json
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.distributed.sharding import hint, logical_to_spec


def test_hint_noop_without_mesh():
    x = jnp.ones((2, 4, 8))
    y = hint(x, "hidden")
    assert y is x


def test_logical_to_spec():
    from jax.sharding import PartitionSpec as PS

    rules = {"fsdp": "data", "tp": "model", "dp": ("data",)}
    assert logical_to_spec(("fsdp", "tp"), rules) == PS("data", "model")
    assert logical_to_spec((None, "tp"), rules) == PS(None, "model")


def _run_subprocess(code: str) -> dict:
    prog = textwrap.dedent(code)
    env = {"PYTHONPATH": "src", "PATH": "/usr/bin:/bin",
           "XLA_FLAGS": "--xla_force_host_platform_device_count=8"}
    # Keep the platform pin (e.g. JAX_PLATFORMS=cpu): without it jax probes
    # for accelerator backends inside the subprocess and can hang for
    # minutes on hosts with a TPU toolchain but no attached TPU.
    for var in ("JAX_PLATFORMS", "JAX_PLATFORM_NAME"):
        if var in os.environ:
            env[var] = os.environ[var]
    out = subprocess.run(
        [sys.executable, "-c", prog],
        capture_output=True,
        text=True,
        timeout=420,
        env=env,
        cwd=".",
    )
    assert out.returncode == 0, out.stderr[-3000:]
    return json.loads(out.stdout.strip().splitlines()[-1])


@pytest.mark.slow
def test_param_sharding_divisibility_8dev():
    res = _run_subprocess("""
        import jax, jax.numpy as jnp, json
        from repro.launch.mesh import make_mesh
        from repro.distributed.sharding import param_sharding
        mesh = make_mesh((2, 4), ("data", "model"))
        specs = {"w": ("fsdp", "tp"), "emb": ("tp", "fsdp")}
        shapes = {"w": jax.ShapeDtypeStruct((16, 8), jnp.float32),
                  "emb": jax.ShapeDtypeStruct((50281, 16), jnp.float32)}
        sh = param_sharding(mesh, specs, shapes_tree=shapes)
        out = {
            "w": str(sh["w"].spec),
            "emb": str(sh["emb"].spec),  # 50281 % 4 != 0 -> tp dropped
        }
        print(json.dumps(out))
    """)
    assert "model" in res["w"]
    assert "model" not in res["emb"]


@pytest.mark.slow
def test_flash_decode_combine_equals_full_softmax_8dev():
    """Distributed partial-softmax over a sequence-sharded KV cache must
    equal single-device attention."""
    res = _run_subprocess("""
        import jax, jax.numpy as jnp, json, numpy as np
        from functools import partial
        from jax.sharding import PartitionSpec as PS
        from jax.experimental.shard_map import shard_map
        from repro.launch.mesh import make_mesh
        from repro.distributed.collectives import (
            local_partial_attention, flash_decode_combine)
        mesh = make_mesh((8,), ("sp",))
        B, H, T, D = 2, 4, 64, 16
        key = jax.random.PRNGKey(0)
        q = jax.random.normal(key, (B, H, 1, D))
        k = jax.random.normal(jax.random.PRNGKey(1), (B, H, T, D))
        v = jax.random.normal(jax.random.PRNGKey(2), (B, H, T, D))
        cur_len = 49

        def shard_fn(q, k, v):
            i = jax.lax.axis_index("sp")
            t_local = k.shape[2]
            pos = i * t_local + jnp.arange(t_local)
            valid = jnp.broadcast_to(pos <= cur_len, (B, t_local))
            m, l, o = local_partial_attention(q, k, v, valid)
            return flash_decode_combine(m, l, o, "sp")

        f = shard_map(shard_fn, mesh=mesh,
                      in_specs=(PS(), PS(None, None, "sp", None),
                                PS(None, None, "sp", None)),
                      out_specs=PS())
        got = f(q, k, v)
        # oracle
        s = jnp.einsum("bhqd,bhtd->bhqt", q, k) * D**-0.5
        s = jnp.where(jnp.arange(T)[None,None,None,:] <= cur_len, s, -1e30)
        w = jax.nn.softmax(s, -1)
        want = jnp.einsum("bhqt,bhtd->bhqd", w, v)
        err = float(jnp.abs(got - want).max())
        print(json.dumps({"err": err}))
    """)
    assert res["err"] < 1e-5


@pytest.mark.slow
def test_compressed_psum_8dev():
    res = _run_subprocess("""
        import jax, jax.numpy as jnp, json, numpy as np
        from jax.sharding import PartitionSpec as PS
        from jax.experimental.shard_map import shard_map
        from repro.launch.mesh import make_mesh
        from repro.optim.compress import compressed_psum, ef_state_init
        mesh = make_mesh((8,), ("dp",))
        g = jax.random.normal(jax.random.PRNGKey(0), (8, 32)) * 0.01
        err0 = jnp.zeros((8, 32))

        def f(g, e):
            out, new_e = compressed_psum({"g": g[0]}, {"g": e[0]}, "dp")
            return out["g"][None], new_e["g"][None]
        fm = shard_map(f, mesh=mesh, in_specs=(PS("dp"), PS("dp")),
                       out_specs=(PS("dp"), PS("dp")))
        summed, resid = fm(g, err0)
        true = jnp.sum(g, axis=0)
        rel = float(jnp.abs(summed[0] - true).max() / (jnp.abs(true).max()+1e-9))
        print(json.dumps({"rel": rel}))
    """)
    assert res["rel"] < 0.05  # int8 quantization error bound


@pytest.mark.slow
def test_elastic_reshard_roundtrip_8dev():
    """Save on a (4,2) mesh layout, restore onto (2,4) — values identical."""
    res = _run_subprocess("""
        import jax, jax.numpy as jnp, json, numpy as np, tempfile
        from repro.launch.mesh import make_mesh
        from repro.distributed.sharding import param_sharding
        from repro.checkpoint.manager import CheckpointManager
        meshA = make_mesh((4, 2), ("data", "model"))
        meshB = make_mesh((2, 4), ("data", "model"))
        specs = {"w": ("fsdp", "tp")}
        w = jnp.arange(64, dtype=jnp.float32).reshape(8, 8)
        shA = param_sharding(meshA, specs, shapes_tree={"w": w})
        tree = {"w": jax.device_put(w, shA["w"])}
        with tempfile.TemporaryDirectory() as d:
            mgr = CheckpointManager(d)
            mgr.save(0, tree, extra={})
            shB = param_sharding(meshB, specs, shapes_tree={"w": w})
            got, _ = mgr.restore(shardings=shB)
            ok = bool(np.array_equal(np.asarray(got["w"]), np.asarray(w)))
            nshards = len(got["w"].sharding.device_set)
        print(json.dumps({"ok": ok, "nshards": nshards}))
    """)
    assert res["ok"] and res["nshards"] == 8


@pytest.mark.slow
def test_dryrun_machinery_small_mesh():
    """The dry-run wiring (abstract model, shardings, lower+compile, cost
    accounting) on a 2x4 mesh with a reduced arch — fast end-to-end proof."""
    res = _run_subprocess("""
        import jax, jax.numpy as jnp, json
        from repro.configs import get_arch
        from repro.configs.base import ShapeConfig
        from repro.launch.mesh import make_mesh
        from repro.launch.steps import (abstract_model, abstract_opt_state,
            input_specs, make_train_step, attn_plan)
        from repro.distributed.sharding import (param_sharding, batch_sharding,
            default_rules, set_activation_mesh)
        from repro.optim.adamw import AdamWConfig
        from jax.sharding import NamedSharding, PartitionSpec as PS
        cfg = get_arch("qwen2-0.5b").reduced()
        shape = ShapeConfig("t", 32, 8, "train")
        mesh = make_mesh((2, 4), ("data", "model"))
        rules = default_rules(mesh)
        set_activation_mesh(mesh, rules)
        plan = attn_plan(cfg, shape)
        ps, specs = abstract_model(cfg, jnp.bfloat16)
        p_sh = param_sharding(mesh, specs, rules, ps)
        os_ = abstract_opt_state(ps)
        o_sh = {"m": p_sh, "v": p_sh, "step": NamedSharding(mesh, PS())}
        batch = input_specs(cfg, shape)
        b_sh = batch_sharding(mesh, batch, rules)
        step = make_train_step(cfg, AdamWConfig(), plan)
        with mesh:
            compiled = jax.jit(step, in_shardings=(p_sh, o_sh, b_sh)).lower(
                ps, os_, batch).compile()
        cost = compiled.cost_analysis()
        if isinstance(cost, (list, tuple)): cost = cost[0]
        print(json.dumps({"flops": float(cost["flops"])}))
    """)
    assert res["flops"] > 0
