"""Symbolic capture adapters == jacobian-sparsity oracle (ground truth)."""

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import capture as C
from repro.core.capture import capture_jacobian

rng = np.random.default_rng(0)


def _rand(shape):
    return rng.random(shape) + 0.5


CASES = [
    ("negative", lambda x: -x, [(4, 3)], lambda: C.identity_lineage((4, 3))),
    ("exp", lambda x: jnp.exp(x), [(5,)], lambda: C.identity_lineage((5,))),
    ("sum_ax1", lambda x: x.sum(axis=1), [(4, 3)], lambda: C.reduce_lineage((4, 3), 1)),
    ("sum_all", lambda x: x.sum().reshape(1), [(3, 3)],
     lambda: C.reduce_lineage((3, 3), (0, 1))),
    ("softmax", lambda x: jnp.exp(x) / jnp.exp(x).sum(-1, keepdims=True), [(3, 4)],
     lambda: C.softmax_lineage((3, 4), -1)),
    ("transpose", lambda x: x.T, [(4, 3)], lambda: C.transpose_lineage((4, 3), (1, 0))),
    ("reshape", lambda x: x.reshape(-1), [(4, 3)],
     lambda: C.reshape_lineage((4, 3), (12,))),
    ("tile", lambda x: jnp.tile(x, (2, 2)), [(3, 2)],
     lambda: C.tile_lineage((3, 2), (2, 2))),
    ("repeat", lambda x: jnp.repeat(x, 3, 0), [(4, 2)],
     lambda: C.repeat_lineage((4, 2), 3, 0)),
    ("roll", lambda x: jnp.roll(x, 2, 0), [(6, 2)], lambda: C.roll_lineage((6, 2), 2, 0)),
    ("flip", lambda x: jnp.flip(x, 0), [(5, 2)], lambda: C.flip_lineage((5, 2), 0)),
    ("pad", lambda x: jnp.pad(x, ((1, 1), (1, 1))), [(3, 3)],
     lambda: C.pad_lineage((3, 3), [(1, 1), (1, 1)])),
    ("slice", lambda x: x[:2, :3], [(5, 6)],
     lambda: C.slice_lineage((5, 6), (0, 0), (2, 3))),
    ("cumsum", lambda x: jnp.cumsum(x), [(7,)], lambda: C.cumulative_lineage(7)),
]


@pytest.mark.parametrize("name,f,shapes,symbolic", CASES, ids=[c[0] for c in CASES])
def test_symbolic_matches_jacobian(name, f, shapes, symbolic):
    args = [_rand(s) for s in shapes]
    got = capture_jacobian(f, *args)[0]
    assert got == symbolic(), name


def test_matmul_both_operands():
    A, B = _rand((3, 4)), _rand((4, 5))
    ra, rb = capture_jacobian(lambda a, b: a @ b, A, B)
    ma, mb = C.matmul_lineage(3, 4, 5)
    assert ra == ma and rb == mb


def test_broadcast_binary():
    x, v = _rand((4, 3)), _rand((3,))
    rx, rv = capture_jacobian(lambda a, b: a * b, x, v)
    assert rx == C.identity_lineage((4, 3))
    assert rv == C.broadcast_lineage((3,), (4, 3))


def test_conv_lineage():
    x = _rand((10,))
    w = _rand((3,))
    rx, rw = capture_jacobian(
        lambda a, b: jnp.convolve(a, b, mode="valid"), x, w
    )
    assert rx == C.conv1d_lineage(10, 3)


def test_sort_value_dependent():
    # sort's jacobian path (gather-under-jacfwd) hits a jax-0.8 batching
    # bug, so mirror what a real capture does for value-dependent ops:
    # derive the permutation from the concrete value, then differentiate
    # the resulting (data-dependent but now fixed) linear map.
    x = rng.permutation(8).astype(float)
    perm = np.argsort(x, kind="stable")
    pmat = np.eye(8)[perm]
    got = capture_jacobian(lambda a: jnp.asarray(pmat) @ a, x)[0]
    assert got == C.sort_lineage(x)


def test_take_lineage():
    idx = np.array([3, 1, 1, 0])
    x = _rand((5, 2))
    got = capture_jacobian(lambda a: a[jnp.asarray(idx)], x)[0]
    assert got == C.take_lineage((5, 2), idx, 0)


def test_group_by_and_join_shapes():
    keys = np.array([2, 1, 2, 0, 1, 2])
    rel = C.group_by_lineage(keys, 3)
    assert rel.out_shape == (3, 3) and rel.in_shape == (6, 3)
    # every input row appears
    assert set(rel.in_idx[:, 0]) == set(range(6))

    lk = np.array([1, 2, 3])
    rk = np.array([2, 2, 4])
    rl, rr = C.inner_join_lineage(lk, rk, 2, 2)
    # key 2 matches twice -> 2 output rows
    assert rl.out_shape[0] == 2
    assert {tuple(r) for r in rl.in_idx} == {(1, 0), (1, 1)}


def test_xai_bipartite_blocks_compress():
    from repro.core.provrc import compress

    rel = C.xai_bipartite_lineage((32, 32), n_out=2, n_patches=3, patch=8)
    t = compress(rel, method="vector")
    assert t.n_rows < rel.n_rows / 10  # block structure must compress
    assert t.decompress() == rel
