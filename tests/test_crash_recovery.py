"""Crash recovery and concurrent ingest: the durability contract.

Two acceptance properties (ISSUE 4):

* **Torn-write crash**: kill the writer at a *random byte offset* of a log
  (simulated by truncating the file there).  Replay must recover exactly
  the intact-record prefix, and every ``prov_query`` answer of the
  recovered store must equal a synchronously-saved oracle built from the
  surviving entries — for ``DSLog`` and ``ShardedDSLog`` with N ∈ {1, 4}.

* **Concurrent writers**: two OS processes ingesting into disjoint shards
  under writer-mode leases produce (after the next exclusive open) a store
  equal to sequential ingest of the same streams.
"""

import glob
import os
import subprocess
import sys
import tempfile
import time

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

import repro.core
from repro.core.capture import (
    flip_lineage,
    identity_lineage,
    roll_lineage,
    transpose_lineage,
)
from repro.core.catalog import DSLog
from repro.core.shard import AffinityShardPolicy, ShardedDSLog
from repro.core.wal import WriteAheadLog

SHAPE = (8, 8)
_HEADER = 15  # WAL magic + base_lsn


@pytest.fixture(autouse=True)
def _race_detect(race_detector):
    """Whole module runs under the dynamic lock-order / race detector."""
    yield

_OPS = [
    lambda rng: identity_lineage(SHAPE),
    lambda rng: flip_lineage(SHAPE, int(rng.integers(0, 2))),
    lambda rng: roll_lineage(SHAPE, int(rng.integers(1, 4)), 0),
    lambda rng: transpose_lineage(SHAPE, (1, 0)),
]


def _ingest_random_dag(log, n_ops: int, seed: int):
    """Chain backbone + random fan-in edges; returns [(lid, src, dst, rel)]."""
    rng = np.random.default_rng(seed)
    names = ["a0"]
    entries = []
    for k in range(n_ops):
        new = f"a{k + 1}"
        rel = _OPS[int(rng.integers(0, len(_OPS)))](rng)
        e = log.add_lineage(names[-1], new, rel)
        entries.append((e.lineage_id, names[-1], new, rel))
        if k % 3 == 2 and len(names) > 2:
            other = names[int(rng.integers(0, len(names) - 1))]
            rel2 = _OPS[int(rng.integers(0, len(_OPS)))](rng)
            e2 = log.add_lineage(other, new, rel2)
            entries.append((e2.lineage_id, other, new, rel2))
        names.append(new)
    return entries


def _sync_saved_oracle(root, entries, survivors):
    """The synchronous baseline: save() after every surviving entry."""
    oracle = DSLog(root=root)
    for lid, src, dst, rel in entries:
        if lid in survivors:
            oracle.add_lineage(src, dst, rel)
            oracle.save()
    if os.path.exists(os.path.join(root, "catalog.json")):
        return DSLog.load(root)
    return oracle


def _answer(store, src, dst, cells):
    """One prov_query answer, normalized: unroutable/unknown -> None."""
    try:
        return store.prov_query(src, dst, cells).cell_set()
    except KeyError:
        return None


def _compare_all_queries(recovered, oracle, arrays):
    cells = np.array([[1, 2], [6, 7]])
    for src in arrays:
        for dst in arrays:
            if src == dst:
                continue
            got = _answer(recovered, src, dst, cells)
            want = _answer(oracle, src, dst, cells)
            assert got == want, (src, dst, got, want)


@settings(max_examples=8, deadline=None)
@given(
    n_ops=st.integers(4, 8),
    seed=st.integers(0, 10_000),
    kind=st.sampled_from(["dslog", "shard1", "shard4"]),
    data=st.data(),
)
def test_torn_write_crash_recovers_to_oracle(n_ops, seed, kind, data):
    with tempfile.TemporaryDirectory() as d, \
            tempfile.TemporaryDirectory() as od:
        if kind == "dslog":
            log = DSLog.open(os.path.join(d, "s"))
        else:
            n = 1 if kind == "shard1" else 4
            log = ShardedDSLog.open(os.path.join(d, "s"), n)
        entries = _ingest_random_dag(log, n_ops, seed)
        # sometimes checkpoint a prefix: recovery must stitch manifested
        # state and the replayed tail together
        ckpt_at = data.draw(st.integers(0, 2), label="ckpt")
        if ckpt_at == 1:
            log.checkpoint()
            extra = _ingest_random_dag(log, 3, seed + 1)
            entries = entries + [
                (lid, s, t, r) for lid, s, t, r in extra
            ]
        log.commit()
        log.close(checkpoint=False)

        # crash: truncate one record-bearing log at a random byte offset
        wals = [
            p
            for p in glob.glob(
                os.path.join(d, "s", "**", "wal.log"), recursive=True
            )
            if os.path.getsize(p) > _HEADER
        ]
        if wals:
            victim = wals[data.draw(st.integers(0, len(wals) - 1), label="wal")]
            size = os.path.getsize(victim)
            cut = data.draw(st.integers(_HEADER, size - 1), label="cut")
            with open(victim, "r+b") as f:
                f.truncate(cut)

        if kind == "dslog":
            recovered = DSLog.load(os.path.join(d, "s"))
            survivors = set(recovered.lineage)
        else:
            recovered = ShardedDSLog.load(os.path.join(d, "s"))
            survivors = set(recovered._lid_shard)
        assert survivors <= {lid for lid, *_ in entries}

        oracle = _sync_saved_oracle(od, entries, survivors)
        arrays = sorted(
            set(recovered.arrays) | set(oracle.arrays),
            key=lambda s: (len(s), s),
        )
        _compare_all_queries(recovered, oracle, arrays)


def test_recovered_store_checkpoints_and_stays_equal():
    """Recovery → checkpoint → reload is a fixed point: the store after
    folding the WAL into manifests answers like the recovered one."""
    with tempfile.TemporaryDirectory() as d:
        root = os.path.join(d, "s")
        log = ShardedDSLog.open(root, 4)
        entries = _ingest_random_dag(log, 7, seed=3)
        log.commit()
        log.close(checkpoint=False)
        first = ShardedDSLog.load(root)
        arrays = sorted(first.arrays)
        cells = np.array([[1, 2], [6, 7]])
        want = {
            (s, t): _answer(first, s, t, cells)
            for s in arrays
            for t in arrays
            if s != t
        }
        with ShardedDSLog.open(root) as excl:  # replays, then checkpoints
            pass
        for k in range(4):  # every shard WAL folded away
            wal = os.path.join(root, f"shard_{k:02d}", "wal.log")
            assert not WriteAheadLog.file_has_records(wal)
        re = ShardedDSLog.load(root)
        assert re.io_stats.get("wal_replayed", 0) == 0
        assert set(re._lid_shard) == {lid for lid, *_ in entries}
        for (s, t), w in want.items():
            assert _answer(re, s, t, cells) == w


# --------------------------------------------------------------------------- #
# Concurrent writer processes (disjoint shards) vs sequential ingest
# --------------------------------------------------------------------------- #
_WORKER = """
import os, sys, time
import numpy as np
from repro.core.shard import ShardedDSLog
from repro.core.capture import identity_lineage, roll_lineage

root, writer, n = sys.argv[1], int(sys.argv[2]), int(sys.argv[3])
go = os.path.join(root, "go")
log = ShardedDSLog.open(root, exclusive=False)
deadline = time.time() + 30
while not os.path.exists(go):  # rendezvous: overlap the ingest windows
    if time.time() > deadline:
        raise SystemExit("rendezvous timed out")
    time.sleep(0.001)
prev = f"w{writer}c0"
for k in range(1, n + 1):
    rel = (identity_lineage((8, 8)) if k % 2 else roll_lineage((8, 8), 1 + k % 3, 0))
    log.add_lineage(prev, f"w{writer}c{k}", rel, op_name=f"op{writer}_{k}")
    prev = f"w{writer}c{k}"
log.close()
"""


def _writer_env():
    env = dict(os.environ)
    src = os.path.abspath(
        os.path.join(os.path.dirname(repro.core.__file__), "..", "..")
    )
    env["PYTHONPATH"] = src + os.pathsep + env.get("PYTHONPATH", "")
    return env


def _sequential_ingest(root, n_writers, n_entries):
    pins = {
        f"w{i}c{k}": i
        for i in range(n_writers)
        for k in range(n_entries + 1)
    }
    with ShardedDSLog.open(
        root, n_writers, policy=AffinityShardPolicy(n_writers, pins)
    ) as log:
        for i in range(n_writers):
            prev = f"w{i}c0"
            for k in range(1, n_entries + 1):
                rel = (
                    identity_lineage((8, 8))
                    if k % 2
                    else roll_lineage((8, 8), 1 + k % 3, 0)
                )
                log.add_lineage(prev, f"w{i}c{k}", rel, op_name=f"op{i}_{k}")
                prev = f"w{i}c{k}"
    return ShardedDSLog.load(root)


@pytest.mark.slow
def test_two_writer_processes_equal_sequential_ingest():
    n_writers, n_entries = 2, 25
    with tempfile.TemporaryDirectory() as d:
        conc_root = os.path.join(d, "conc")
        seq_root = os.path.join(d, "seq")
        pins = {
            f"w{i}c{k}": i
            for i in range(n_writers)
            for k in range(n_entries + 1)
        }
        with ShardedDSLog.open(
            conc_root, n_writers, policy=AffinityShardPolicy(n_writers, pins)
        ):
            pass  # initialize the store (policy pins each chain to a shard)

        procs = [
            subprocess.Popen(
                [sys.executable, "-c", _WORKER, conc_root, str(i), str(n_entries)],
                env=_writer_env(),
                stdout=subprocess.PIPE,
                stderr=subprocess.PIPE,
            )
            for i in range(n_writers)
        ]
        time.sleep(0.2)  # let both reach the rendezvous loop
        with open(os.path.join(conc_root, "go"), "w") as f:
            f.write("go")
        for p in procs:
            out, err = p.communicate(timeout=120)
            assert p.returncode == 0, err.decode()

        # the next exclusive open folds both writers' WALs into manifests
        with ShardedDSLog.open(conc_root):
            pass
        conc = ShardedDSLog.load(conc_root)
        seq = _sequential_ingest(seq_root, n_writers, n_entries)

        # identical stores: same ids on the same shards, same topology,
        # same ops, same query answers
        assert conc._lid_shard == seq._lid_shard
        assert set(conc.by_pair) == set(seq.by_pair)
        assert sorted(
            (op.op_name, op.in_arrs, op.out_arrs) for op in conc.ops
        ) == sorted((op.op_name, op.in_arrs, op.out_arrs) for op in seq.ops)
        cells = np.array([[3, 4]])
        for i in range(n_writers):
            got = conc.prov_query(f"w{i}c{n_entries}", f"w{i}c0", cells)
            want = seq.prov_query(f"w{i}c{n_entries}", f"w{i}c0", cells)
            assert got.cell_set() == want.cell_set()


def test_crash_between_shard_and_root_manifest_keeps_topology(monkeypatch):
    """Checkpoint ordering: shard WALs must stay replayable until the root
    manifest is durably written, or a crash in between loses the new
    cross-shard edges from the global topology."""
    import repro.core.catalog as catalog_mod
    import repro.core.shard as shard_mod

    with tempfile.TemporaryDirectory() as d:
        log = ShardedDSLog.open(d, 4)
        entries = _ingest_random_dag(log, 6, seed=9)
        real_write = catalog_mod._atomic_write

        def crash_on_root(path, payload):
            if os.path.dirname(path) == d:  # the root manifest itself
                raise OSError("simulated crash before root manifest")
            return real_write(path, payload)

        monkeypatch.setattr(catalog_mod, "_atomic_write", crash_on_root)
        monkeypatch.setattr(shard_mod, "_atomic_write", crash_on_root)
        with pytest.raises(OSError):
            log.save()  # shard manifests land, root write "crashes"
        monkeypatch.setattr(catalog_mod, "_atomic_write", real_write)
        monkeypatch.setattr(shard_mod, "_atomic_write", real_write)
        log.close(checkpoint=False)

        re = ShardedDSLog.load(d)
        assert set(re._lid_shard) == {lid for lid, *_ in entries}
        cells = np.array([[1, 2]])
        last = max(int(s[1:]) for s in re.arrays if s.startswith("a"))
        got = _answer(re, f"a{last}", "a0", cells)
        oracle = DSLog()
        for lid, s, t, rel in entries:
            oracle.add_lineage(s, t, rel)
        assert got == _answer(oracle, f"a{last}", "a0", cells)


def test_idle_writer_blocks_exclusive_open():
    """A writer-mode process that has not written yet (no shard lease)
    must still be visible: its presence slot blocks an exclusive open,
    whose checkpoint would otherwise truncate the shared root log under a
    live appender."""
    with tempfile.TemporaryDirectory() as d:
        with ShardedDSLog.open(d, 2):
            pass
        from repro.core.commit import LeaseHeldError

        w = ShardedDSLog.open(d, exclusive=False)
        with pytest.raises(LeaseHeldError):
            ShardedDSLog.open(d)
        w.close()
        with ShardedDSLog.open(d):  # presence released: works again
            pass


def test_readonly_load_never_truncates_a_live_log():
    """DSLog.load holds no lease; a writer's in-flight (torn-looking)
    bytes at the log tail must survive a concurrent load."""
    with tempfile.TemporaryDirectory() as d:
        log = DSLog.open(d)
        log.add_lineage("A", "B", identity_lineage((5,)))
        log.commit()
        wal = os.path.join(d, "wal.log")
        size = os.path.getsize(wal)
        with open(wal, "r+b") as f:  # a partial record reaching the OS
            f.seek(0, 2)
            f.write(b"\x99\x03\x00\x00partial")
        re = DSLog.load(d)
        assert os.path.getsize(wal) == size + 11  # untouched
        assert len(re.lineage) == 1
        log.close(checkpoint=False)


def test_cross_writer_cycle_is_quarantined_not_wedged():
    """Two writers can each pass their local cycle check yet jointly close
    a cross-shard cycle; recovery must quarantine the later entry, never
    leave a store that cannot load."""
    with tempfile.TemporaryDirectory() as d:
        pol = AffinityShardPolicy(2, {"x": 0, "y": 1})
        with ShardedDSLog.open(d, 2, policy=pol):
            pass
        wa = ShardedDSLog.open(d, exclusive=False)
        wb = ShardedDSLog.open(d, exclusive=False)
        wa.add_lineage("x", "y", identity_lineage((4,)))  # dst shard 1
        wb.add_lineage("y", "x", identity_lineage((4,)))  # dst shard 0: cycle
        wa.close()
        wb.close()
        with ShardedDSLog.open(d) as merged:  # must not raise
            assert len(merged._lid_shard) == 1
        re = ShardedDSLog.load(d)
        assert len(re._lid_shard) == 1


# --------------------------------------------------------------------------- #
# Parallel plan execution
# --------------------------------------------------------------------------- #
def _fanin_dag(log, branches=6, side=16):
    shape = (side, side)
    log.define_array("src", shape)
    mids = [f"m{b}" for b in range(branches)]
    for m in mids:
        log.define_array(m, shape)
    log.define_array("mid", shape)
    log.register_operation(
        "fanout", ["src"], mids,
        capture=lambda: {
            (b, 0): roll_lineage(shape, b + 1, 0) for b in range(branches)
        },
        reuse=False,
    )
    log.register_operation(
        "combine", mids, ["mid"],
        capture=lambda: {
            (0, b): identity_lineage(shape) for b in range(branches)
        },
        reuse=False,
    )
    log.define_array("out", shape)
    log.register_operation(
        "tail", ["mid"], ["out"],
        capture=lambda: {(0, 0): flip_lineage(shape, 1)},
        reuse=False,
    )
    return log


@pytest.mark.parametrize("make", [lambda: DSLog(), lambda: ShardedDSLog(n_shards=4)])
def test_parallel_execution_equals_serial(make):
    log = _fanin_dag(make())
    cells = np.array([[2, 3], [7, 9], [12, 1]])
    queries = [cells, cells[:1]]
    for src, dst in [("src", "out"), ("out", "src")]:
        serial = log.prov_query_batch(src, dst, queries)
        par = log.prov_query_batch(src, dst, queries, parallel=4)
        assert [r.cell_set() for r in serial] == [r.cell_set() for r in par]
        assert [r.lo.tobytes() for r in serial] == [r.lo.tobytes() for r in par]


def test_planner_parallel_attribute_is_default():
    log = _fanin_dag(ShardedDSLog(n_shards=2))
    want = log.prov_query("src", "out", np.array([[5, 5]])).cell_set()
    log.planner.parallel = 3
    assert log.prov_query("src", "out", np.array([[5, 5]])).cell_set() == want


def test_parallel_execution_on_lazy_reloaded_store():
    """Worker threads racing onto the same lazy blob must load it once."""
    with tempfile.TemporaryDirectory() as d:
        _fanin_dag(ShardedDSLog(n_shards=4, root=d)).save()
        re = ShardedDSLog.load(d)
        res = re.prov_query("out", "src", np.array([[4, 4]]), parallel=4)
        want = _fanin_dag(DSLog()).prov_query("out", "src", np.array([[4, 4]]))
        assert res.cell_set() == want.cell_set()
        total = sum(1 + e.has_forward for e in re.lineage.values())
        assert re.io_stats["tables_loaded"] <= total
