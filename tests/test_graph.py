"""LineageGraph: adjacency, reachability, path sets, cycle rejection."""

import numpy as np
import pytest

from repro.core.capture import identity_lineage, reduce_lineage
from repro.core.catalog import DSLog
from repro.core.graph import CycleError, LineageGraph


def _diamond() -> LineageGraph:
    g = LineageGraph()
    g.add_edge("x", "a", 0)
    g.add_edge("x", "b", 1)
    g.add_edge("a", "z", 2)
    g.add_edge("b", "z", 3)
    return g


def test_adjacency_and_edge_ids():
    g = _diamond()
    assert sorted(g.successors("x")) == ["a", "b"]
    assert sorted(g.predecessors("z")) == ["a", "b"]
    assert g.edge_ids("x", "a") == [0]
    assert g.edge_ids("a", "x") == []
    g.add_edge("x", "a", 7)  # parallel entry on an existing edge
    assert g.edge_ids("x", "a") == [0, 7]
    assert g.n_edges() == 5
    assert len(g) == 4 and "x" in g


def test_reachability_both_directions():
    g = _diamond()
    g.add_edge("z", "out", 4)
    assert g.reachable("x") == {"x", "a", "b", "z", "out"}
    assert g.reachable("a") == {"a", "z", "out"}
    assert g.reachable("z", "backward") == {"z", "a", "b", "x"}
    assert g.has_path("x", "out") and not g.has_path("out", "x")
    # set-valued starts
    assert g.reachable({"a", "b"}) == {"a", "b", "z", "out"}


def test_cycle_rejection_leaves_graph_untouched():
    g = _diamond()
    with pytest.raises(CycleError):
        g.add_edge("z", "x", 9)
    with pytest.raises(CycleError):
        g.add_edge("x", "x", 9)
    assert g.n_edges() == 4
    assert "z" not in g.fwd or "x" not in g.fwd.get("z", {})


def test_simple_paths_between_sets():
    g = _diamond()
    g.add_edge("z", "out", 4)
    paths = g.simple_paths("x", "z")
    assert sorted(paths) == [["x", "a", "z"], ["x", "b", "z"]]
    # endpoint sets: either branch node to either sink
    paths = g.simple_paths({"a", "b"}, {"z", "out"})
    assert ["a", "z"] in paths and ["b", "z", "out"] in paths
    assert len(paths) == 4
    # a target upstream of another target still terminates paths at both
    assert ["a", "z"] in g.simple_paths("a", {"z", "out"})
    assert g.simple_paths("out", "x") == []
    assert g.simple_paths("x", "z", max_paths=1) == [["x", "a", "z"]]


def test_induced_subdag_and_topo_order():
    g = _diamond()
    g.add_edge("z", "out", 4)
    g.add_edge("stray", "other", 5)
    nodes, edges = g.induced_subdag("x", "z")
    assert nodes == {"x", "a", "b", "z"}
    assert ("z", "out") not in edges and len(edges) == 4
    order = g.topo_order(nodes)
    assert order[0] == "x" and order[-1] == "z"
    assert order.index("a") < order.index("z")
    assert order.index("b") < order.index("z")
    # deterministic tie-break by name
    assert order == ["x", "a", "b", "z"]


def test_catalog_builds_graph_incrementally():
    log = DSLog()
    log.add_lineage("X", "Y", identity_lineage((4, 3)))
    log.add_lineage("Y", "Z", reduce_lineage((4, 3), 1))
    assert log.graph.has_path("X", "Z")
    assert log.graph.edge_ids("X", "Y") == [0]
    # registering an op adds its edges too
    log.define_array("W", (4,))
    log.register_operation(
        "relu", ["Z"], ["W"], capture=lambda: {(0, 0): identity_lineage((4,))}
    )
    assert log.graph.has_path("X", "W")


def test_catalog_rejects_cyclic_lineage():
    log = DSLog()
    log.add_lineage("X", "Y", identity_lineage((4,)))
    with pytest.raises(CycleError):
        log.add_lineage("Y", "X", identity_lineage((4,)))
    # the failed add must not leave a dangling entry behind
    assert ("Y", "X") not in log.by_pair
    assert len(log.lineage) == 1


def test_remove_edge_rollback():
    g = _diamond()
    g.add_edge("x", "a", 7)
    g.remove_edge("x", "a", 7)
    assert g.edge_ids("x", "a") == [0]
    g.remove_edge("x", "a", 0)
    assert g.edge_ids("x", "a") == []
    assert "a" not in g.successors("x")
    g.remove_edge("x", "a", 99)  # absent id: no-op
    # with the edge gone, the reverse direction is insertable again
    g.add_edge("a", "x", 8)
    assert g.has_path("a", "z") and g.has_path("a", "x")


def test_register_operation_is_atomic_on_cycle():
    """A multi-entry op whose later pair closes a cycle must roll back the
    sibling entries it already inserted (and observe nothing)."""
    log = DSLog()
    log.add_lineage("u", "v", identity_lineage((4,)))
    log.define_array("w", (4,))
    log.define_array("x", (4,))
    n_before = len(log.lineage)
    with pytest.raises(CycleError):
        # (0,0) w->... fine; in-place second output closes v->u... use
        # out list where first pair inserts cleanly, second is cyclic
        log.register_operation(
            "op", ["v", "w"], ["x", "u"],
            capture=lambda: {
                (0, 0): identity_lineage((4,)),  # v -> x (fine)
                (1, 0): identity_lineage((4,)),  # v -> u (closes u->v->u)
            },
            reuse=False,
        )
    assert len(log.lineage) == n_before
    assert ("v", "x") not in log.by_pair
    assert log.graph.edge_ids("v", "x") == []
    assert log.ops == []  # no half-registered op record
