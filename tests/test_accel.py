"""Batched accelerator execution of plan steps (ISSUE 5).

The contract under test: the :class:`BatchedJoinExecutor` — packing a plan
frontier's dense joins into one blocked evaluation — returns **bit-identical**
results to the serial per-hop join loop, for DSLog and ShardedDSLog, serial
and ``parallel=N``; and the Pallas dense path's padding, int32-overflow, and
lane-capacity limits are enforced instead of silently wrong.
"""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.catalog import DSLog
from repro.core.query import (
    BatchedJoinExecutor,
    JoinRequest,
    QueryBox,
    dense_backend,
    theta_join,
    theta_join_batch,
    theta_join_inverse_batch,
)
from repro.core.shard import ShardedDSLog
from repro.core.table import CompressedTable

from test_shard import SHAPE, SIDE, _build_random_dag

rng = np.random.default_rng(11)


@pytest.fixture(autouse=True)
def _race_detect(race_detector):
    """Covers the parallel∈{2,4} plan-execution cases below."""
    yield


def _random_table(nr, l=2, m=2, span=500, seed=None):
    r = np.random.default_rng(seed if seed is not None else rng.integers(1 << 30))
    key_lo = r.integers(0, span, (nr, l))
    key_hi = key_lo + r.integers(0, 4, (nr, l))
    val_lo = r.integers(-3, 0, (nr, m))
    val_hi = val_lo + r.integers(0, 6, (nr, m))
    return CompressedTable(
        key_shape=(span + 10,) * l,
        val_shape=(span + 10,) * m,
        key_lo=key_lo,
        key_hi=key_hi,
        val_lo=val_lo,
        val_hi=val_hi,
        val_ref=r.integers(0, l, (nr, m)),
    )


def _boxes(shape, n, span=400, width=30, seed=0):
    r = np.random.default_rng(seed)
    lo = r.integers(0, span, (n, len(shape)))
    return QueryBox(shape, lo, lo + r.integers(0, width, (n, len(shape))))


def _assert_boxes_equal(a, b):
    assert a.shape == b.shape
    assert a.lo.tobytes() == b.lo.tobytes()
    assert a.hi.tobytes() == b.hi.tobytes()


# --------------------------------------------------------------------------- #
# Executor parity vs the per-request joins
# --------------------------------------------------------------------------- #
def test_executor_matches_per_request_joins_exactly():
    """Every route/direction/merge combination, one packed run."""
    reqs, oracle = [], []
    for trial in range(10):
        t = _random_table(int(rng.integers(1, 2000)))
        inverse = trial % 2 == 1
        shape = t.val_shape if inverse else t.key_shape
        qs = [
            _boxes(shape, int(rng.integers(0, 25)), seed=trial * 7 + j)
            for j in range(int(rng.integers(0, 3)))
        ]
        for path in ("auto", "dense", "index", "batched"):
            merge = (trial + len(reqs)) % 3 == 0
            reqs.append(
                JoinRequest(qs, t, inverse=inverse, merge=merge, path=path)
            )
            fn = theta_join_inverse_batch if inverse else theta_join_batch
            oracle.append(fn(qs, t, merge=merge, path=path))
    got = BatchedJoinExecutor().run(reqs)
    assert len(got) == len(oracle)
    for g_list, w_list in zip(got, oracle):
        assert len(g_list) == len(w_list)
        for g, w in zip(g_list, w_list):
            _assert_boxes_equal(g, w)


def test_executor_worker_count_is_bit_identical():
    reqs = [
        JoinRequest(
            [_boxes((510,) * 2, 40, seed=k)],
            _random_table(600, seed=k),
            merge=False,
            path="dense",
        )
        for k in range(9)
    ]
    want = BatchedJoinExecutor().run(reqs)
    for workers in (2, 4, 9):
        got = BatchedJoinExecutor().run(reqs, workers=workers)
        for g_list, w_list in zip(got, want):
            for g, w in zip(g_list, w_list):
                _assert_boxes_equal(g, w)


# --------------------------------------------------------------------------- #
# Property test: batched prov_query == per-hop oracle on random DAGs
# --------------------------------------------------------------------------- #
@settings(max_examples=10, deadline=None)
@given(
    n_ops=st.integers(4, 9),
    seed=st.integers(0, 10_000),
    n_shards=st.sampled_from([1, 4]),
)
def test_batched_execution_equals_perhop_oracle(n_ops, seed, n_shards):
    log = DSLog()
    sharded = ShardedDSLog(n_shards=n_shards)
    names = _build_random_dag([log, sharded], n_ops, seed)
    r = np.random.default_rng(seed + 1)
    cells = np.stack([r.integers(0, SIDE, 3), r.integers(0, SIDE, 3)], axis=1)
    src, dst = names[0], names[-1]
    for store in (log, sharded):
        for s, t, q in [(src, dst, cells), (dst, src, cells[:1])]:
            want = store.prov_query(s, t, q, batched=False)
            for kw in (
                dict(batched=True),
                dict(batched=True, parallel=2),
                dict(batched=True, parallel=4),
            ):
                got = store.prov_query(s, t, q, **kw)
                _assert_boxes_equal(got, want)
        # path form through the same engines
        path = [src, names[1], names[2]]
        want = store.prov_query(path, cells, batched=False)
        got = store.prov_query(path, cells, batched=True)
        _assert_boxes_equal(got, want)


def _force_kernel_engine(store):
    """Pin the store's batched executor to the segmented Pallas kernel path
    (interpreted here — no TPU), replacing the planner's lazy default."""
    store.planner._executor = BatchedJoinExecutor(
        stats=store._bump,
        tuner=getattr(store, "autotune", None),
        engine="kernel",
    )


@settings(max_examples=6, deadline=None)
@given(
    n_ops=st.integers(4, 8),
    seed=st.integers(0, 10_000),
    n_shards=st.sampled_from([1, 4]),
)
def test_kernel_engine_blockdiag_equals_perhop_oracle(n_ops, seed, n_shards):
    """ISSUE 8 tentpole, end to end: ``engine="kernel"`` forces every dense
    segment through ``segmented_range_join_pairs`` (block-diagonal schedule
    when the frontier warrants it) — bit-identical to the per-hop loop on
    random DAGs, DSLog and ShardedDSLog, serial and parallel, under the
    autouse race detector."""
    log = DSLog()
    sharded = ShardedDSLog(n_shards=n_shards)
    names = _build_random_dag([log, sharded], n_ops, seed)
    r = np.random.default_rng(seed + 1)
    cells = np.stack([r.integers(0, SIDE, 3), r.integers(0, SIDE, 3)], axis=1)
    src, dst = names[0], names[-1]
    for store in (log, sharded):
        store.views.enabled = False  # answer cache would serve the repeats
        want = store.prov_query(src, dst, cells, batched=False)
        _force_kernel_engine(store)
        for kw in (
            dict(batched=True),
            dict(batched=True, parallel=2),
            dict(batched=True, parallel=4),
        ):
            _assert_boxes_equal(store.prov_query(src, dst, cells, **kw), want)
        assert store.io_stats["batch_tiles_visited"] > 0


def test_batch_and_multi_target_forms_parity():
    log = DSLog()
    names = _build_random_dag([log], 7, seed=42)
    r = np.random.default_rng(5)
    cells = np.stack([r.integers(0, SIDE, 4), r.integers(0, SIDE, 4)], axis=1)
    src, dst = names[0], names[-1]
    want = log.prov_query_batch(src, dst, [cells, cells[:2]], batched=False)
    got = log.prov_query_batch(src, dst, [cells, cells[:2]], batched=True)
    for g, w in zip(got, want):
        _assert_boxes_equal(g, w)
    mids = [names[2], dst]
    want_m = log.prov_query(src, mids, cells, batched=False)
    got_m = log.prov_query(src, mids, cells, batched=True, parallel=2)
    assert set(got_m) == set(want_m)
    for k in want_m:
        _assert_boxes_equal(got_m[k], want_m[k])


# --------------------------------------------------------------------------- #
# io_stats batching meters
# --------------------------------------------------------------------------- #
def test_io_stats_meter_batched_dispatches():
    log = DSLog()
    names = _build_random_dag([log], 6, seed=9)
    cells = np.array([[1, 2], [5, 6]])
    base = dict(log.io_stats)
    log.prov_query(names[0], names[-1], cells, batched=True)
    assert log.io_stats["kernel_launches"] > base["kernel_launches"]
    assert log.io_stats["joins_packed"] > base["joins_packed"]
    assert (
        log.io_stats["batch_rows_padded"] >= log.io_stats["batch_rows"] > 0
    )
    # tile meters (ISSUE 8): every dense dispatch charges its schedule
    assert log.io_stats["batch_tiles_visited"] > 0
    assert log.io_stats["batch_tiles_skipped"] >= 0
    # per-hop loop does not touch the batching meters
    base = dict(log.io_stats)
    log.prov_query(names[0], names[-1], cells, batched=False)
    assert log.io_stats["kernel_launches"] == base["kernel_launches"]


def test_sharded_io_stats_aggregate_batching_counters():
    sharded = ShardedDSLog(n_shards=2)
    names = _build_random_dag([sharded], 6, seed=9)
    sharded.prov_query(names[0], names[-1], np.array([[1, 2]]), batched=True)
    assert sharded.io_stats["kernel_launches"] > 0
    # the facade aggregates the tile meters across root + shards
    assert sharded.io_stats["batch_tiles_visited"] > 0
    assert "batch_tiles_skipped" in sharded.io_stats


# --------------------------------------------------------------------------- #
# Bugfix: int32 overflow routes to the numpy dense path
# --------------------------------------------------------------------------- #
def _huge_coord_table(n=40):
    """Value bounds beyond 2**31: an int32 pack would silently wrap."""
    big = np.int64(2) ** 33
    r = np.random.default_rng(0)
    key_lo = r.integers(0, 50, (n, 1))
    key_hi = key_lo + r.integers(0, 3, (n, 1))
    val_lo = key_lo * (big // 50)
    val_hi = val_lo + 5
    return CompressedTable(
        key_shape=(100,),
        val_shape=(int(big * 2),),
        key_lo=key_lo,
        key_hi=key_hi,
        val_lo=val_lo,
        val_hi=val_hi,
        val_ref=np.full((n, 1), -1),
    )


def test_int64_coordinates_join_correctly_via_numpy_dense():
    t = _huge_coord_table()
    q = QueryBox((100,), np.array([[0]]), np.array([[60]]))
    res = theta_join(q, t, merge=False, path="dense")
    # oracle: every overlapping key row contributes its value interval
    hits = (t.key_lo[:, 0] <= 60) & (t.key_hi[:, 0] >= 0)
    assert res.n_rows == int(hits.sum())
    assert res.lo.min() >= 0 and res.hi.max() >= 2**31  # no wraparound
    # inverse direction probes the huge value bounds
    qv = QueryBox(t.val_shape, t.val_lo[:1], t.val_hi[:1])
    res_inv = theta_join_inverse_batch([qv], t, merge=False, path="dense")[0]
    assert res_inv.n_rows >= 1


def test_kernel_path_refuses_int64_and_twin_handles_it(monkeypatch):
    from repro.core import query as qmod
    from repro.kernels import ops

    big = np.full((4, 2), 2**31 + 7, np.int64)
    small = np.zeros((4, 2), np.int64)
    # the packer raises loudly instead of wrapping
    with pytest.raises(ValueError, match="int32"):
        ops.range_join_pairs(big, big, big, big)
    # _kernel_pairs routes away (returns None) even when a device is claimed
    monkeypatch.setattr(ops, "default_interpret", lambda: False)
    assert qmod._kernel_pairs(big, big, small, small + 10) is None


def test_executor_skips_kernel_pack_for_overflowing_segment():
    """With a forced non-interpret executor, int64 segments take the twin."""
    t_small = _random_table(80, seed=1)
    t_big = _huge_coord_table()
    qv = QueryBox(t_big.val_shape, t_big.val_lo[:2] - 1, t_big.val_hi[:2] + 1)
    reqs = [
        JoinRequest([_boxes(t_small.key_shape, 10)], t_small, path="dense"),
        JoinRequest([qv], t_big, inverse=True, path="dense"),
    ]
    want = [
        theta_join_batch(reqs[0].queries, t_small, path="dense"),
        theta_join_inverse_batch([qv], t_big, path="dense"),
    ]
    # interpret=True: everything through the twin (this container has no TPU;
    # the kernel-eligibility partition itself is covered by fits_int32 tests)
    got = BatchedJoinExecutor(interpret=True).run(reqs)
    for g_list, w_list in zip(got, want):
        for g, w in zip(g_list, w_list):
            _assert_boxes_equal(g, w)


# --------------------------------------------------------------------------- #
# Bugfix: lane capacity is an explicit limit, visible in plan.describe()
# --------------------------------------------------------------------------- #
def test_high_dimensional_table_joins_via_numpy(monkeypatch):
    """65 key attributes: 2*65 > 128 lanes — kernel refuses, numpy serves."""
    from repro.kernels import ops

    l = 65
    n = 30
    r = np.random.default_rng(3)
    key_lo = r.integers(0, 4, (n, l))
    t = CompressedTable(
        key_shape=(8,) * l,
        val_shape=(8,),
        key_lo=key_lo,
        key_hi=key_lo + 1,
        val_lo=r.integers(0, 4, (n, 1)),
        val_hi=r.integers(4, 8, (n, 1)),
        val_ref=np.full((n, 1), -1),
    )
    q = QueryBox((8,) * l, np.zeros((2, l)), np.full((2, l), 7))
    res = theta_join(q, t, merge=False, path="dense")
    assert res.n_rows == 2 * n  # full overlap: every (row, box) pair
    with pytest.raises(ValueError, match="lane capacity"):
        ops.range_join_pairs(key_lo, key_lo + 1, key_lo, key_lo + 1)
    # even with a device claimed, the dense route must fall back, not raise
    monkeypatch.setattr(ops, "default_interpret", lambda: False)
    from repro.core.query import _kernel_pairs

    assert _kernel_pairs(q.lo, q.hi, t.key_lo, t.key_hi) is None
    assert dense_backend(l) == "np:wide"


def test_describe_shows_route_backend_notes():
    log = DSLog(store_forward=True)
    log.define_array("a", SHAPE)
    log.define_array("b", SHAPE)
    from repro.core.capture import identity_lineage

    log.add_lineage("a", "b", identity_lineage(SHAPE))
    plan = log.planner.plan("a", ["b"])
    text = plan.describe()
    assert "batched(" in text  # routing decision + backend note are visible
    assert "np:" in text  # this container has no TPU
    # the per-hop engine plans the same hops as plain dense
    log.planner.batched = False
    assert "dense" in log.planner.plan("a", ["b"]).describe()
    log.planner.batched = True


def test_sharded_describe_shows_notes():
    sharded = ShardedDSLog(n_shards=2)
    names = _build_random_dag([sharded], 5, seed=2)
    text = sharded.planner.plan(names[0], [names[-1]]).describe()
    assert "(" in text and "np:" in text


# --------------------------------------------------------------------------- #
# ISSUE 8: autotuned launch geometry — persistence, invalidation, notes
# --------------------------------------------------------------------------- #
def test_autotune_table_persists_across_save_load(tmp_path):
    """A tuned (backend, bucket) winner survives the catalog round-trip via
    the ``autotune.json`` sidecar, on both the single store and the sharded
    facade."""
    d1, d2 = str(tmp_path / "single"), str(tmp_path / "sharded")
    log = DSLog(root=d1)
    log.define_array("a", SHAPE)
    geom, _ = log.autotune.pick(
        "interpret", "k3q5r5w2",
        runner=lambda g: g, candidates=((128, 128), (256, 256)), warmup=False,
    )
    assert log.autotune.dirty
    log.save()
    assert not log.autotune.dirty
    log2 = DSLog.load(d1)
    assert log2.autotune.lookup("interpret", "k3q5r5w2") == geom

    sharded = ShardedDSLog(n_shards=2, root=d2)
    names = _build_random_dag([sharded], 4, seed=1)
    sharded.autotune.pick(
        "np", "k2q4r4w2",
        runner=lambda g: g, candidates=((1 << 20,), (1 << 22,)), warmup=False,
    )
    sharded.save()
    re = ShardedDSLog.load(d2)
    assert re.autotune.lookup("np", "k2q4r4w2") is not None
    # queries still answer identically on the reopened store
    cells = np.array([[1, 1], [2, 3]])
    _assert_boxes_equal(
        re.prov_query(names[0], names[-1], cells, batched=True),
        sharded.prov_query(names[0], names[-1], cells, batched=False),
    )


def test_autotune_cache_invalidated_by_backend_change():
    """Entries are backend-keyed: a table tuned under one backend never
    answers another (the store-moved-machines case), and a manifest whose
    entries disagree with their keys loads cold."""
    from repro.kernels.autotune import GeometryTuner

    t = GeometryTuner()
    t.pick("interpret", "k1q2r2w1",
           runner=lambda g: g, candidates=((64, 128),), warmup=False)
    assert t.lookup("interpret", "k1q2r2w1") == (64, 128)
    assert t.lookup("tpu", "k1q2r2w1") is None  # backend changed -> re-tune
    manifest = t.to_manifest()
    # simulate a table written on another backend: key says tpu, rec says
    # interpret — the loader must drop it rather than mislead a lookup
    manifest["entries"] = {
        "tpu|k1q2r2w1": dict(manifest["entries"]["interpret|k1q2r2w1"])
    }
    t2 = GeometryTuner()
    t2.load_manifest(manifest)
    assert t2.lookup("tpu", "k1q2r2w1") is None
    assert len(t2) == 0
    t2.load_manifest({"version": 1, "entries": "garbage"})  # torn -> cold
    assert len(t2) == 0


def test_describe_note_renders_launch_geometry():
    """ISSUE 8 satellite: the hop note names the engine's launch geometry —
    ``batched(np:cpu:4m)`` on this box (twin, 4M-cell mask blocks)."""
    log = DSLog(store_forward=True)
    log.define_array("a", SHAPE)
    log.define_array("b", SHAPE)
    from repro.core.capture import identity_lineage

    log.add_lineage("a", "b", identity_lineage(SHAPE))
    text = log.planner.plan("a", ["b"]).describe()
    assert "batched(np:cpu:4m)" in text
    # a tuned twin geometry shows up in later notes
    log.planner.executor._last_geometry["np"] = (1 << 20,)
    assert "np:cpu:1m" in log.planner.plan("a", ["b"]).describe()


def test_planner_discount_tracks_measured_occupancy():
    """The batched-route discount widens back toward 1 as the executor
    observes tile waste — cold executors keep the flat prior."""
    from repro.core.planner import _BATCHED_PAIR_DISCOUNT

    log = DSLog()
    log.define_array("a", SHAPE)
    p = log.planner
    assert p._batched_discount() == pytest.approx(_BATCHED_PAIR_DISCOUNT)
    p.executor._observe_occupancy(tile_cells=100_000, useful_cells=100)
    assert p.executor.measured_waste > 1.0
    assert p._batched_discount() > _BATCHED_PAIR_DISCOUNT
    assert p._batched_discount() <= 1.0
