"""ProvRC compression: paper examples, losslessness (property-based),
compression-quality guarantees on structured patterns, serialization."""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.capture import (
    conv1d_lineage,
    identity_lineage,
    matmul_lineage,
    reduce_lineage,
    softmax_lineage,
    sort_lineage,
    tile_lineage,
)
from repro.core.provrc import compress, compress_both
from repro.core.relation import LineageRelation
from repro.core.table import CompressedTable

METHODS = ["paper", "vector"]


# --------------------------------------------------------------------------- #
# Paper worked examples
# --------------------------------------------------------------------------- #
@pytest.mark.parametrize("method", METHODS)
def test_paper_fig1_sum_axis1(method):
    """B = sum(A, axis=1) for A 3x2 (paper Fig 1 / Tables I-II)."""
    rel = LineageRelation.from_pairs(
        (3,), (3, 2), [((b,), (b, a)) for b in range(3) for a in range(2)]
    )
    t = compress(rel, "backward", method)
    assert t.n_rows == 1
    # key b spans [0, 2]; a0 is delta-0 relative to b; a1 is absolute [0, 1]
    assert t.key_lo[0, 0] == 0 and t.key_hi[0, 0] == 2
    assert t.val_ref[0, 0] == 0 and t.val_lo[0, 0] == 0 and t.val_hi[0, 0] == 0
    assert t.val_ref[0, 1] == -1 and (t.val_lo[0, 1], t.val_hi[0, 1]) == (0, 1)
    assert t.decompress() == rel


@pytest.mark.parametrize("method", METHODS)
def test_paper_fig2_aggregate_all(method):
    """4x4 -> 1x1 all-to-all aggregation (paper Fig 2)."""
    rel = LineageRelation.from_pairs(
        (1,), (4, 4), [((0,), (i, j)) for i in range(4) for j in range(4)]
    )
    t = compress(rel, "backward", method)
    assert t.n_rows == 1
    assert t.decompress() == rel


@pytest.mark.parametrize("method", METHODS)
def test_paper_fig6_reshaping_base(method):
    """1-D aggregate compresses to the single-row form Fig 6 generalizes."""
    rel = LineageRelation.from_pairs((1,), (2,), [((0,), (0,)), ((0,), (1,))])
    t = compress(rel, "backward", method)
    assert t.n_rows == 1
    assert (t.val_lo[0, 0], t.val_hi[0, 0]) == (0, 1)


# --------------------------------------------------------------------------- #
# Structured patterns: O(1)-row guarantees (paper Table VII structure)
# --------------------------------------------------------------------------- #
@pytest.mark.parametrize("method", METHODS)
def test_elementwise_one_row(method):
    t = compress(identity_lineage((64, 32)), method=method)
    assert t.n_rows == 1


@pytest.mark.parametrize("method", METHODS)
def test_matmul_constant_rows(method):
    ra, rb = matmul_lineage(16, 12, 8)
    for rel in (ra, rb):
        t = compress(rel, method=method)
        assert t.n_rows == 1
        assert t.decompress() == rel


@pytest.mark.parametrize("method", METHODS)
def test_conv_constant_rows(method):
    rel = conv1d_lineage(100, 5)
    t = compress(rel, method=method)
    assert t.n_rows == 1
    assert t.decompress() == rel


def test_reduce_softmax_tile_small():
    # (relation, max rows): tile is piecewise-delta — one row per replica
    cases = [
        (reduce_lineage((12, 7), 0), 1),
        (softmax_lineage((6, 9), -1), 1),
        (tile_lineage((5, 4), (2, 3)), 6),
    ]
    for rel, max_rows in cases:
        for method in METHODS:
            t = compress(rel, method=method)
            assert t.n_rows <= max_rows, (method, t.n_rows)
            assert t.decompress() == rel


def test_sort_incompressible():
    """Sort is the paper's worst case: no contiguous patterns survive."""
    rng = np.random.default_rng(0)
    rel = sort_lineage(rng.random(128))
    t = compress(rel, method="vector")
    assert t.n_rows > 100  # essentially uncompressed
    assert t.decompress() == rel


def test_vector_not_worse_than_paper_greedy():
    rng = np.random.default_rng(1)
    for _ in range(10):
        n = int(rng.integers(5, 80))
        o = rng.integers(0, 6, (n, 2))
        i = rng.integers(0, 6, (n, 2))
        rel = LineageRelation((6, 6), (6, 6), o, i).canonical()
        t_paper = compress(rel, method="paper")
        t_vec = compress(rel, method="vector")
        assert t_vec.n_rows <= t_paper.n_rows


# --------------------------------------------------------------------------- #
# Losslessness (property-based)
# --------------------------------------------------------------------------- #
@settings(max_examples=60, deadline=None)
@given(
    data=st.data(),
    l=st.integers(1, 2),
    m=st.integers(1, 2),
    method=st.sampled_from(METHODS),
)
def test_lossless_roundtrip_random(data, l, m, method):
    oshape = tuple(data.draw(st.integers(1, 5)) for _ in range(l))
    ishape = tuple(data.draw(st.integers(1, 5)) for _ in range(m))
    n = data.draw(st.integers(1, 40))
    rng = np.random.default_rng(data.draw(st.integers(0, 2**31)))
    o = np.stack([rng.integers(0, s, n) for s in oshape], axis=1)
    i = np.stack([rng.integers(0, s, n) for s in ishape], axis=1)
    rel = LineageRelation(oshape, ishape, o, i).canonical()
    bwd, fwd = compress_both(rel, method=method)
    assert bwd.decompress() == rel
    assert fwd.decompress() == rel


# --------------------------------------------------------------------------- #
# Serialization
# --------------------------------------------------------------------------- #
def test_serialize_roundtrip():
    rel = reduce_lineage((9, 5), 1)
    t = compress(rel)
    for compress_flag in (False, True):
        blob = t.serialize(compress=compress_flag)
        t2 = CompressedTable.deserialize(blob)
        assert t2.decompress() == rel
        assert t2.key_shape == t.key_shape and t2.direction == t.direction


def test_packed_size_beats_raw():
    rel = identity_lineage((1000,))
    t = compress(rel)
    assert t.nbytes() < rel.nbytes_raw() / 100
