"""WAL record format, group commit, leases, and the DSLog durability surface.

The crash-*equivalence* properties (torn tail at a random offset vs the
synchronous-save oracle) live in ``test_crash_recovery.py``; this module
covers the mechanisms those properties rest on.
"""

import os
import tempfile
import threading

import numpy as np
import pytest

from repro.core.capture import identity_lineage, reduce_lineage
from repro.core.catalog import DSLog
from repro.core.commit import CommitPipeline, LeaseHeldError, WriterLease
from repro.core.wal import WriteAheadLog


@pytest.fixture(autouse=True)
def _race_detect(race_detector):
    """Whole module runs under the dynamic lock-order / race detector."""
    yield


# --------------------------------------------------------------------------- #
# Record format and torn-tail truncation
# --------------------------------------------------------------------------- #
def test_wal_round_trip_records_and_blobs():
    with tempfile.TemporaryDirectory() as d:
        p = os.path.join(d, "wal.log")
        w = WriteAheadLog(p)
        w.append("entry", {"id": 7, "src": "a"}, [b"backward-bytes", b"fwd"])
        w.append("op", {"op": "neg", "args": None})
        w.flush()
        recs = WriteAheadLog(p).recover()
        assert [r.type for r in recs] == ["entry", "op"]
        assert recs[0].meta == {"id": 7, "src": "a"}
        assert recs[0].blobs == [b"backward-bytes", b"fwd"]
        assert recs[1].blobs == []
        # LSNs are end offsets, strictly increasing
        assert 0 < recs[0].lsn < recs[1].lsn == w.end_lsn


def test_wal_truncates_torn_tail_at_any_cut():
    """Cutting the file anywhere inside the last record must recover the
    full prefix before it — whole-record atomicity of the log."""
    with tempfile.TemporaryDirectory() as d:
        p = os.path.join(d, "wal.log")
        w = WriteAheadLog(p)
        w.append("a", {"k": 1}, [b"xx"])
        mid = w.end_lsn
        w.append("b", {"k": 2}, [b"yyyy"])
        w.flush()
        full = os.path.getsize(p)
        header = full - (w.end_lsn - mid)  # file offset where record b starts
        for cut in range(header, full):
            with tempfile.TemporaryDirectory() as d2:
                p2 = os.path.join(d2, "wal.log")
                with open(p, "rb") as f:
                    data = f.read()
                with open(p2, "wb") as f:
                    f.write(data[:cut])
                recs = WriteAheadLog(p2).recover()
                assert [r.type for r in recs] == ["a"], f"cut at {cut}"
                assert recs[0].blobs == [b"xx"]
                # the torn bytes are gone: appends continue cleanly
                w2 = WriteAheadLog(p2)
                w2.append("c", {})
                w2.flush()
                assert [r.type for r in WriteAheadLog(p2).recover()] == ["a", "c"]


def test_wal_crc_corruption_drops_tail():
    with tempfile.TemporaryDirectory() as d:
        p = os.path.join(d, "wal.log")
        w = WriteAheadLog(p)
        w.append("a", {})
        w.append("b", {})
        w.flush()
        size = os.path.getsize(p)
        with open(p, "r+b") as f:  # flip one byte inside the last record
            f.seek(size - 1)
            byte = f.read(1)
            f.seek(size - 1)
            f.write(bytes([byte[0] ^ 0xFF]))
        recs = WriteAheadLog(p).recover()
        assert [r.type for r in recs] == ["a"]


def test_wal_checkpoint_keeps_lsns_monotonic():
    with tempfile.TemporaryDirectory() as d:
        p = os.path.join(d, "wal.log")
        w = WriteAheadLog(p)
        w.append("a", {})
        w.flush()
        ck = w.checkpoint()
        assert ck == w.base_lsn and not w.has_records
        w.append("b", {})
        w.flush()
        assert w.end_lsn > ck
        # replay past the checkpoint sees only the new record
        recs = WriteAheadLog(p).recover(min_lsn=ck)
        assert [r.type for r in recs] == ["b"]
        # a pre-checkpoint min_lsn cannot resurrect truncated records
        assert [r.type for r in WriteAheadLog(p).recover(min_lsn=0)] == ["b"]


def test_wal_shared_append_overwrites_torn_tail():
    """A crashed writer's torn tail must not strand later flock-appended
    records behind it (repair() would discard them); shared flush rewinds
    to the last intact boundary."""
    with tempfile.TemporaryDirectory() as d:
        p = os.path.join(d, "wal.log")
        a = WriteAheadLog(p, shared=True)
        a.append("a", {})
        a.flush()
        with open(p, "r+b") as f:  # crashed writer's partial record
            f.seek(0, 2)
            f.write(b"\xff\xff\x00\x00torn-partial-bytes")
        b = WriteAheadLog(p, shared=True)
        b.append("b", {"k": 1})
        b.flush()
        w = WriteAheadLog(p)
        w.repair()  # exclusive repair must not discard b's record
        assert [r.type for r in w.recover()] == ["a", "b"]


def test_wal_shared_mode_interleaves_whole_records():
    with tempfile.TemporaryDirectory() as d:
        p = os.path.join(d, "wal.log")
        a = WriteAheadLog(p, shared=True)
        b = WriteAheadLog(p, shared=True)
        for i in range(5):
            a.append("a", {"i": i})
            b.append("b", {"i": i})
        a.flush()
        b.flush()
        recs = WriteAheadLog(p).recover()
        assert sorted(r.type for r in recs) == ["a"] * 5 + ["b"] * 5
        # per-writer order is preserved
        for t in ("a", "b"):
            assert [r.meta["i"] for r in recs if r.type == t] == list(range(5))


# --------------------------------------------------------------------------- #
# Group commit
# --------------------------------------------------------------------------- #
def test_group_commit_amortizes_fsyncs():
    with tempfile.TemporaryDirectory() as d:
        w = WriteAheadLog(os.path.join(d, "wal.log"))
        pipe = CommitPipeline(mode="group", flush_interval=0.5, max_batch=8)
        pipe.attach(w)
        for _ in range(32):
            w.append("e", {})
            pipe.notify(w)
        pipe.commit()
        assert pipe.stats["synced_records"] == 32
        # 32 records cost ~4 batch fsyncs, not 32
        assert w.stats["syncs"] <= 8
        pipe.close()
        assert len(WriteAheadLog(w.path).recover()) == 32


def test_sync_mode_fsyncs_every_record():
    with tempfile.TemporaryDirectory() as d:
        w = WriteAheadLog(os.path.join(d, "wal.log"))
        pipe = CommitPipeline(mode="sync")
        pipe.attach(w)
        for _ in range(5):
            w.append("e", {})
            pipe.notify(w)
        assert w.stats["syncs"] == 5
        pipe.close()


def test_group_commit_interval_flushes_in_background():
    with tempfile.TemporaryDirectory() as d:
        w = WriteAheadLog(os.path.join(d, "wal.log"))
        pipe = CommitPipeline(mode="group", flush_interval=0.01, max_batch=10_000)
        pipe.attach(w)
        w.append("e", {})
        pipe.notify(w)
        deadline = __import__("time").time() + 2.0
        while pipe.stats["synced_records"] < 1:
            if __import__("time").time() > deadline:
                raise AssertionError("interval flusher never fired")
            __import__("time").sleep(0.005)
        pipe.close()


# --------------------------------------------------------------------------- #
# Writer leases
# --------------------------------------------------------------------------- #
def test_lease_excludes_second_writer_and_releases():
    with tempfile.TemporaryDirectory() as d:
        lease = WriterLease.acquire(d)
        assert WriterLease.held(d)
        with pytest.raises(LeaseHeldError):
            WriterLease.acquire(d)
        lease.release()
        lease.release()  # idempotent
        assert not WriterLease.held(d)
        WriterLease.acquire(d).release()


def test_stale_lease_of_dead_pid_is_stolen():
    with tempfile.TemporaryDirectory() as d:
        import json
        import socket

        path = os.path.join(d, WriterLease.FILENAME)
        with open(path, "w") as f:  # a crashed writer's leftover lease
            json.dump(
                {"pid": 2**22 + 12345, "host": socket.gethostname(), "token": "x"},
                f,
            )
        assert not WriterLease.held(d)
        lease = WriterLease.acquire(d)  # steals, no error
        assert WriterLease.held(d)
        lease.release()


def test_release_does_not_remove_someone_elses_lease():
    with tempfile.TemporaryDirectory() as d:
        lease = WriterLease.acquire(d)
        os.remove(lease.path)
        other = WriterLease.acquire(d)
        lease.release()  # token mismatch: must leave the new lease alone
        assert WriterLease.held(d)
        other.release()


# --------------------------------------------------------------------------- #
# DSLog durability surface
# --------------------------------------------------------------------------- #
def test_dslog_open_is_context_managed_and_single_writer():
    with tempfile.TemporaryDirectory() as d:
        with DSLog.open(d) as log:
            log.add_lineage("A", "B", identity_lineage((6, 3)))
            with pytest.raises(LeaseHeldError):
                DSLog.open(d)
        # exit checkpointed (manifest exists, WAL truncated) + released
        assert os.path.exists(os.path.join(d, "catalog.json"))
        assert not WriteAheadLog.file_has_records(os.path.join(d, "wal.log"))
        assert not WriterLease.held(d)
        with DSLog.open(d) as log2:  # reopen after release works
            res = log2.prov_query("B", "A", np.array([[4, 1]]))
            assert res.cell_set() == {(4, 1)}


def test_dslog_load_replays_wal_without_manifest():
    """A crash before the first checkpoint leaves only a WAL; load() must
    reconstruct the catalog from it alone."""
    with tempfile.TemporaryDirectory() as d:
        log = DSLog.open(d)
        log.add_lineage("A", "B", identity_lineage((6, 3)))
        log.add_lineage("B", "C", reduce_lineage((6, 3), 1))
        log.version("acc", shape=(4,))
        log.commit()
        log.close(checkpoint=False)
        assert not os.path.exists(os.path.join(d, "catalog.json"))

        re = DSLog.load(d)
        assert re.io_stats["wal_replayed"] >= 3
        assert re.prov_query("C", "A", np.array([[2]])).cell_set() == {
            (2, 0), (2, 1), (2, 2)
        }
        assert re.latest_version("acc") == "acc@1"
        # recovery composes with checkpointing: save, reload, no replay
        re.save()
        re2 = DSLog.load(d)
        assert re2.io_stats.get("wal_replayed", 0) == 0
        assert len(re2.lineage) == 2


def test_checkpoint_skips_already_manifested_records():
    """Crash between manifest write and WAL truncation: replay must skip
    records at or below the manifest's checkpoint LSN."""
    with tempfile.TemporaryDirectory() as d:
        log = DSLog.open(d)
        log.add_lineage("A", "B", identity_lineage((5,)))
        # simulate the torn checkpoint: save writes the manifest, then we
        # resurrect the WAL bytes as if truncation never happened
        log.commit()
        with open(os.path.join(d, "wal.log"), "rb") as f:
            wal_bytes = f.read()
        log.checkpoint()
        log.close(checkpoint=False)
        with open(os.path.join(d, "wal.log"), "wb") as f:
            f.write(wal_bytes)
        re = DSLog.load(d)
        assert len(re.lineage) == 1  # not doubled
        assert re.io_stats.get("wal_replayed", 0) == 0


def test_mark_dirty_persists_inplace_mutation_across_crash():
    with tempfile.TemporaryDirectory() as d:
        log = DSLog.open(d, store_forward=False)
        e = log.add_lineage("a", "b", identity_lineage((8,)))
        log.checkpoint()
        t = e.backward  # mutate the stored table in place: shift values +1
        t.val_lo[:] = t.val_lo + 1
        t.val_hi[:] = t.val_hi + 1
        log.mark_dirty(e.lineage_id)
        log.commit()
        log.close(checkpoint=False)  # crash before the next checkpoint

        re = DSLog.load(d)
        assert re.prov_query("b", "a", np.array([[3]])).cell_set() == {(4,)}
        re.save()  # ...and the next checkpoint persists it to the manifest
        re2 = DSLog.load(d)
        assert re2.prov_query("b", "a", np.array([[3]])).cell_set() == {(4,)}


def test_mark_dirty_unknown_id_raises():
    log = DSLog()
    with pytest.raises(KeyError):
        log.mark_dirty(99)


def test_dropped_entry_stays_dropped_after_replay():
    with tempfile.TemporaryDirectory() as d:
        log = DSLog.open(d)
        e = log.add_lineage("a", "b", identity_lineage((5,)))
        log.add_lineage("b", "c", identity_lineage((5,)))
        log.drop_lineage(e.lineage_id)
        log.commit()
        log.close(checkpoint=False)
        re = DSLog.load(d)
        assert set(re.lineage) == {1}
        with pytest.raises(KeyError):
            re.prov_query("b", "a", np.array([[1]]))


def test_unleased_save_never_truncates_a_live_log():
    """save() on a merely load()-ed store (the pre-WAL workflow) records
    the checkpoint LSN but must NOT truncate the log — a live leased
    writer may be appending to it."""
    with tempfile.TemporaryDirectory() as d:
        writer = DSLog.open(d)
        writer.add_lineage("A", "B", identity_lineage((5,)))
        writer.commit()
        reader = DSLog.load(d)
        reader.save()
        assert WriteAheadLog.file_has_records(os.path.join(d, "wal.log"))
        writer.add_lineage("B", "C", identity_lineage((5,)))
        writer.commit()
        writer.close(checkpoint=False)
        re = DSLog.load(d)
        assert len(re.lineage) == 2  # the writer's later record survived


def test_legacy_store_gains_durability_on_first_open():
    """Opening a pre-WAL store with DSLog.open must create the log — a
    mutation after open survives a crash without any save()."""
    with tempfile.TemporaryDirectory() as d:
        legacy = DSLog(root=d)
        legacy.add_lineage("A", "B", identity_lineage((5,)))
        legacy.save()
        log = DSLog.open(d)
        log.add_lineage("B", "C", identity_lineage((5,)))
        log.commit()
        log.close(checkpoint=False)
        re = DSLog.load(d)
        assert len(re.lineage) == 2
        assert re.prov_query("C", "A", np.array([[2]])).cell_set() == {(2,)}


def test_legacy_store_without_wal_is_untouched():
    """Plain DSLog(root)/save()/load() must not create any WAL artifacts."""
    with tempfile.TemporaryDirectory() as d:
        log = DSLog(root=d)
        log.add_lineage("A", "B", identity_lineage((5,)))
        log.save()
        assert not os.path.exists(os.path.join(d, "wal.log"))
        assert not os.path.exists(os.path.join(d, WriterLease.FILENAME))
        re = DSLog.load(d)
        assert re._wal is None
        assert re.prov_query("B", "A", np.array([[2]])).cell_set() == {(2,)}


# --------------------------------------------------------------------------- #
# Cost-feedback aging (hop_stats decay)
# --------------------------------------------------------------------------- #
def test_hop_stats_decay_tracks_workload_shift():
    log = DSLog(hop_decay=0.5)
    # old regime: 100 pairs per query row, observed many times
    for _ in range(50):
        log.record_hop(0, "backward", "key", pairs=1000, qrows=10)
    assert log.hop_measurement(0, "backward", "key") == pytest.approx(100.0)
    # workload shifts: 2 pairs per row.  With decay the EMA converges fast;
    # an un-aged accumulator would still read ~51 after 50 observations.
    for _ in range(50):
        log.record_hop(0, "backward", "key", pairs=20, qrows=10)
    m = log.hop_measurement(0, "backward", "key")
    assert m == pytest.approx(2.0, rel=0.01)


def test_hop_sample_cap_bounds_history():
    from repro.core.catalog import _HOP_SAMPLE_CAP

    log = DSLog(hop_decay=1.0)  # no decay: only the cap bounds the mass
    for _ in range(10):
        log.record_hop(0, "backward", "key", pairs=1, qrows=int(_HOP_SAMPLE_CAP))
    st = log.hop_stats[log._hop_key(0, "backward", "key")]
    assert st[1] <= _HOP_SAMPLE_CAP * (1 + 1e-9)


def test_hop_decay_round_trips_in_manifest():
    with tempfile.TemporaryDirectory() as d:
        log = DSLog(root=d, hop_decay=0.25, store_forward=False)
        log.add_lineage("a", "b", identity_lineage((8, 8)))
        log.prov_query("b", "a", np.array([[3, 3]]))
        m = log.hop_measurement(0, "backward", "key")
        log.save()
        re = DSLog.load(d)
        assert re.hop_decay == 0.25
        assert re.hop_measurement(0, "backward", "key") == pytest.approx(m)


def test_record_hop_is_thread_safe():
    log = DSLog(hop_decay=1.0)

    def work():
        for _ in range(200):
            log.record_hop(0, "backward", "key", pairs=1, qrows=1)

    threads = [threading.Thread(target=work) for _ in range(4)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    st = log.hop_stats[log._hop_key(0, "backward", "key")]
    assert st[0] == st[1] == pytest.approx(800.0)
