"""Sharded store: N=1 equivalence, cross-shard correctness, persistence.

The contract under test (ISSUE 3): ``ShardedDSLog`` with ``N=1`` is the
single store — byte-identical query results — and for ``N > 1`` every
``prov_query`` form returns the single-store answer while entries live on
different shards, frontiers cross boundaries as merged boxes, and each
shard saves independently.
"""

import os
import tempfile

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.capture import (
    flip_lineage,
    identity_lineage,
    reduce_lineage,
    roll_lineage,
    transpose_lineage,
)
from repro.core.catalog import DSLog
from repro.core.graph import CycleError
from repro.core.shard import (
    AffinityShardPolicy,
    HashShardPolicy,
    ShardedDSLog,
    ShardedQueryPlan,
)

SIDE = 8
SHAPE = (SIDE, SIDE)


@pytest.fixture(autouse=True)
def _race_detect(race_detector):
    """Whole module runs under the dynamic lock-order / race detector."""
    yield

# shape-preserving single-input ops for the random-DAG property test
_OPS = [
    lambda rng: identity_lineage(SHAPE),
    lambda rng: flip_lineage(SHAPE, int(rng.integers(0, 2))),
    lambda rng: roll_lineage(SHAPE, int(rng.integers(1, 4)), 0),
    lambda rng: transpose_lineage(SHAPE, (1, 0)),
]


def _build_random_dag(logs, n_ops: int, seed: int):
    """Drive identical op streams into several stores.

    A chain backbone (a0 → a1 → …) guarantees a route end to end; every
    third op is a two-input fan-in whose second parent is a random earlier
    array — under hashing those parents regularly land on distinct shards.
    """
    rng = np.random.default_rng(seed)
    names = ["a0"]
    for log in logs:
        log.define_array("a0", SHAPE)
    for k in range(n_ops):
        new = f"a{k + 1}"
        prev = names[-1]
        fan_in = k % 3 == 2 and len(names) > 2
        if fan_in:
            other = names[int(rng.integers(0, len(names) - 1))]
            state = rng.bit_generator.state
            for log in logs:
                rng.bit_generator.state = state  # same draws per store
                rel_a = _OPS[int(rng.integers(0, len(_OPS)))](rng)
                rel_b = _OPS[int(rng.integers(0, len(_OPS)))](rng)
                log.define_array(new, SHAPE)
                log.register_operation(
                    f"op{k}", [prev, other], [new],
                    capture=lambda ra=rel_a, rb=rel_b: {(0, 0): ra, (0, 1): rb},
                    reuse=False,
                )
        else:
            state = rng.bit_generator.state
            for log in logs:
                rng.bit_generator.state = state
                rel = _OPS[int(rng.integers(0, len(_OPS)))](rng)
                log.define_array(new, SHAPE)
                log.register_operation(
                    f"op{k}", [prev], [new],
                    capture=lambda r=rel: {(0, 0): r},
                    reuse=False,
                )
        names.append(new)
    return names


def _diamond(log, pins=None):
    """x fans out to a and b, which fan back into z (explicit affinity)."""
    log.define_array("x", SHAPE)
    log.define_array("a", SHAPE)
    log.define_array("b", SHAPE)
    log.define_array("z", SHAPE)
    log.register_operation(
        "split", ["x"], ["a", "b"],
        capture=lambda: {
            (0, 0): flip_lineage(SHAPE, 0),
            (1, 0): roll_lineage(SHAPE, 2, 1),
        },
        reuse=False,
    )
    log.register_operation(
        "combine", ["a", "b"], ["z"],
        capture=lambda: {
            (0, 0): identity_lineage(SHAPE),
            (0, 1): identity_lineage(SHAPE),
        },
        reuse=False,
    )
    return log


# --------------------------------------------------------------------------- #
# N=1: the single-store special case
# --------------------------------------------------------------------------- #
def test_n1_query_results_byte_identical():
    single = _diamond(DSLog())
    sharded = _diamond(ShardedDSLog(n_shards=1))
    cells = np.array([[2, 3], [7, 0]])
    for src, dst, q in [
        ("x", "z", cells),
        ("z", "x", np.array([[4, 4]])),
        ("x", "a", cells),
    ]:
        a = single.prov_query(src, dst, q)
        b = sharded.prov_query(src, dst, q)
        assert a.shape == b.shape
        assert a.lo.tobytes() == b.lo.tobytes()
        assert a.hi.tobytes() == b.hi.tobytes()
    # path form too
    a = single.prov_query(["z", "a", "x"], np.array([[1, 1]]))
    b = sharded.prov_query(["z", "a", "x"], np.array([[1, 1]]))
    assert a.lo.tobytes() == b.lo.tobytes() and a.hi.tobytes() == b.hi.tobytes()
    # the sharded plan is the single-store plan: no exchanges, one shard
    plan = sharded.planner.plan("x", ["z"])
    assert isinstance(plan, ShardedQueryPlan)
    assert plan.exchanges == [] and plan.shards_touched() == [0]


def test_n1_manifest_layout_and_reload():
    with tempfile.TemporaryDirectory() as d:
        _diamond(ShardedDSLog(n_shards=1, root=d)).save()
        assert os.path.exists(os.path.join(d, "catalog.json"))
        assert os.path.exists(os.path.join(d, "shard_00", "catalog.json"))
        re = ShardedDSLog.load(d)
        got = re.prov_query("z", "x", np.array([[4, 4]]))
        want = _diamond(DSLog()).prov_query("z", "x", np.array([[4, 4]]))
        assert got.lo.tobytes() == want.lo.tobytes()
        with pytest.raises(ValueError):
            DSLog.load(d)  # sharded roots refuse the single-store loader
        with pytest.raises(ValueError):
            ShardedDSLog.load(os.path.join(d, "shard_00"))  # and vice versa


# --------------------------------------------------------------------------- #
# Cross-shard correctness vs the single-store oracle
# --------------------------------------------------------------------------- #
@settings(max_examples=10, deadline=None)
@given(
    n_ops=st.integers(4, 9),
    seed=st.integers(0, 10_000),
    n_shards=st.sampled_from([1, 2, 4]),
)
def test_sharded_query_equals_single_store(n_ops, seed, n_shards):
    oracle = DSLog()
    sharded = ShardedDSLog(n_shards=n_shards)
    names = _build_random_dag([oracle, sharded], n_ops, seed)
    rng = np.random.default_rng(seed + 1)
    cells = np.stack(
        [rng.integers(0, SIDE, 3), rng.integers(0, SIDE, 3)], axis=1
    )
    src, dst = names[0], names[-1]
    for s, t, q in [(src, dst, cells), (dst, src, cells[:1])]:
        for merge in (True, False):
            want = oracle.prov_query(s, t, q, merge=merge).cell_set()
            got = sharded.prov_query(s, t, q, merge=merge).cell_set()
            assert got == want
    # batch + multi-target forms
    want_b = oracle.prov_query_batch(src, dst, [cells, cells[:1]])
    got_b = sharded.prov_query_batch(src, dst, [cells, cells[:1]])
    assert [r.cell_set() for r in got_b] == [r.cell_set() for r in want_b]
    mids = names[1 : len(names) - 1 : 2]
    if mids:
        want_m = oracle.prov_query(src, mids + [dst], cells)
        got_m = sharded.prov_query(src, mids + [dst], cells)
        assert {k: v.cell_set() for k, v in got_m.items()} == {
            k: v.cell_set() for k, v in want_m.items()
        }


def test_fanin_parents_on_different_shards():
    """The acceptance case: a fan-in array whose parents live on different
    shards — results match the single store, frontiers cross as exchanges."""
    pol = AffinityShardPolicy(2, {"x": 0, "a": 0, "b": 1, "z": 1})
    sharded = _diamond(ShardedDSLog(n_shards=2, policy=pol))
    oracle = _diamond(DSLog())
    assert sharded.shard_of_array("a") != sharded.shard_of_array("b")
    assert len(sharded.sgraph.boundary) > 0
    cells = np.array([[2, 3], [5, 5]])
    fwd = sharded.planner.plan("x", ["z"], frontier=None)
    assert fwd.exchanges, "fan-in across shards must ship a frontier"
    for s, t, q in [("x", "z", cells), ("z", "x", np.array([[4, 4]]))]:
        assert (
            sharded.prov_query(s, t, q).cell_set()
            == oracle.prov_query(s, t, q).cell_set()
        )
    assert sharded.io_stats["boxes_exchanged"] > 0
    # per-shard sub-plans partition the steps of the stitched plan
    subs = fwd.sub_plans()
    n_steps = sum(len(sl) for sl in fwd.steps.values())
    assert sum(len(sl) for p in subs.values() for sl in p.steps.values()) == n_steps
    assert set(subs) == set(fwd.shards_touched())


def test_sharded_graph_partition_is_consistent():
    pol = AffinityShardPolicy(3, {"x": 0, "a": 1, "b": 2, "z": 0})
    log = _diamond(ShardedDSLog(n_shards=3, policy=pol))
    g = log.sgraph
    # per-shard edge counts sum to the global count
    assert sum(sg.n_edges() for sg in g.shard_graphs) == g.n_edges() == 4
    # boundary table lists exactly the cross-shard entries
    for lid, src, dst, s_sh, d_sh in g.boundary_edges():
        assert s_sh != d_sh
        assert log.owner_shard(lid) == d_sh
        entry = log.lineage[lid]
        assert (entry.src, entry.dst) == (src, dst)
    # every edge is in the dst-owner's shard graph
    for (src, dst), ids in log.by_pair.items():
        shard = log.shard_of_array(dst)
        assert set(g.shard_graph(shard).edge_ids(src, dst)) == set(ids)


def test_sharded_cycle_rejection_spans_shards():
    pol = AffinityShardPolicy(2, {"u": 0, "v": 1, "w": 0})
    log = ShardedDSLog(n_shards=2, policy=pol)
    log.add_lineage("u", "v", identity_lineage(SHAPE))
    log.add_lineage("v", "w", identity_lineage(SHAPE))
    with pytest.raises(CycleError):
        log.add_lineage("w", "u", identity_lineage(SHAPE))
    with pytest.raises(CycleError):
        log.add_lineage("u", "u", identity_lineage(SHAPE))
    # the rejected edges left nothing behind, queries still work
    assert len(log.lineage) == 2
    res = log.prov_query("w", "u", np.array([[3, 3]]))
    assert res.cell_set() == {(3, 3)}


# --------------------------------------------------------------------------- #
# Persistence: dirty shards only, lazy shard loading
# --------------------------------------------------------------------------- #
def test_incremental_save_writes_only_dirty_shards():
    with tempfile.TemporaryDirectory() as d:
        pol = AffinityShardPolicy(3, {"u": 0, "v": 0, "p": 1, "q": 1})
        log = ShardedDSLog(n_shards=3, root=d, policy=pol)
        log.add_lineage("u", "v", identity_lineage((6, 3)))
        log.add_lineage("p", "q", reduce_lineage((6, 3), 1))
        log.save()
        base = log.io_stats
        # shard 2 never hosted an entry: no directory, no manifest
        assert not os.path.exists(os.path.join(d, "shard_02", "catalog.json"))

        log.save()  # clean save: nothing at all is written
        assert log.io_stats["manifests_written"] == base["manifests_written"]
        assert log.io_stats["tables_written"] == base["tables_written"]

        mtime_s1 = os.path.getmtime(os.path.join(d, "shard_01", "catalog.json"))
        log.add_lineage("v", "w", identity_lineage((6, 3)), op_name="grow")
        new_lid = log.by_pair[("v", "w")][0]
        dirty_shard = log.owner_shard(new_lid)  # the new entry's owning shard
        log.save()
        after = log.io_stats
        # exactly the dirty shard's manifest + the root manifest rewrote
        assert after["manifests_written"] == base["manifests_written"] + 2
        assert after["tables_written"] == base["tables_written"] + 2
        if dirty_shard != 1:
            assert (
                os.path.getmtime(os.path.join(d, "shard_01", "catalog.json"))
                == mtime_s1
            )


def test_lazy_shard_loading_on_query():
    with tempfile.TemporaryDirectory() as d:
        pol = AffinityShardPolicy(2, {"u": 0, "v": 0, "p": 1, "q": 1})
        log = ShardedDSLog(n_shards=2, root=d, policy=pol)
        log.add_lineage("u", "v", identity_lineage((6, 3)))
        log.add_lineage("p", "q", reduce_lineage((6, 3), 1))
        log.save()

        re = ShardedDSLog.load(d)
        assert re.io_stats["shards_loaded"] == 0
        # the graph came from the root manifest — no shard I/O to route
        assert re.graph.has_path("u", "v") and not re.graph.has_path("u", "q")
        res = re.prov_query("v", "u", np.array([[4, 1]]))
        assert res.cell_set() == {(4, 1)}
        # only the plan-touched shard loaded, and only one blob inside it
        assert re.io_stats["shards_loaded"] == 1
        assert re.loaded_shards() == [0]
        assert re.io_stats["tables_loaded"] == 1


def test_sharded_round_trip_extends_incrementally():
    with tempfile.TemporaryDirectory() as d:
        log = ShardedDSLog(n_shards=4, root=d)
        names = _build_random_dag([log], 6, seed=3)
        log.save()
        re = ShardedDSLog.load(d)
        re.define_array("tail", SHAPE)
        re.add_lineage(names[-1], "tail", identity_lineage(SHAPE))
        re.save()
        re2 = ShardedDSLog.load(d)
        oracle = DSLog()
        _build_random_dag([oracle], 6, seed=3)
        oracle.add_lineage(names[-1], "tail", identity_lineage(SHAPE))
        cells = np.array([[1, 2], [6, 7]])
        assert (
            re2.prov_query(names[0], "tail", cells).cell_set()
            == oracle.prov_query(names[0], "tail", cells).cell_set()
        )


def test_sharded_version_and_compact():
    with tempfile.TemporaryDirectory() as d:
        log = ShardedDSLog(n_shards=2, root=d)
        log.define_array("acc", (5,))
        prev = log.latest_version("acc")
        for _ in range(3):
            cur = log.version("acc")
            log.add_lineage(prev, cur, identity_lineage((5,)))
            prev = cur
        assert prev == "acc@3"
        # version chains co-locate: no boundary edges, no exchanges
        assert log.sgraph.boundary == {}
        res = log.prov_query("acc@3", "acc", np.array([[2]]))
        assert res.cell_set() == {(2,)}
        log.save()
        dropped = log.by_pair[("acc@2", "acc@3")][0]
        owner = log.owner_shard(dropped)
        assert any(  # the query above recorded feedback for this hop
            k.startswith(f"{dropped}:") for k in log.shard(owner).hop_stats
        )
        log.drop_lineage(dropped)
        assert not any(
            k.startswith(f"{dropped}:") for k in log.shard(owner).hop_stats
        )
        stats = log.compact()
        assert stats["files_removed"] >= 2  # backward + forward blobs
        re = ShardedDSLog.load(d)
        assert re.latest_version("acc") == "acc@3"
        assert re.version("acc") == "acc@4"
        assert dropped not in re.lineage


# --------------------------------------------------------------------------- #
# Cost-model feedback on the sharded planner
# --------------------------------------------------------------------------- #
def test_hop_feedback_routes_to_owning_shard():
    with tempfile.TemporaryDirectory() as d:
        pol = AffinityShardPolicy(2, {"x": 0, "a": 0, "b": 1, "z": 1})
        log = _diamond(ShardedDSLog(n_shards=2, root=d, policy=pol))
        log.prov_query("z", "x", np.array([[4, 4]]))
        # measurements landed on the shard owning each entry
        measured = {
            lid: log.hop_measurement(lid, "backward", "key")
            for lid in log.lineage
        }
        assert any(v is not None for v in measured.values())
        for lid, val in measured.items():
            shard = log.shard(log.owner_shard(lid))
            if val is not None:
                assert shard.hop_measurement(lid, "backward", "key") == val
        log.save()
        re = ShardedDSLog.load(d)
        for lid, val in measured.items():
            if val is not None:
                assert re.hop_measurement(lid, "backward", "key") == val
