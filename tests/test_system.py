"""End-to-end behaviour tests: DSLog over real multi-op array workflows,
with every query checked against the uncompressed-rows oracle."""

import numpy as np
import pytest

from repro.core import DSLog, QueryBox
from repro.core.capture import (
    capture_jacobian,
    conv2d_lineage,
    flip_lineage,
    identity_lineage,
    inner_join_lineage,
    reduce_lineage,
    softmax_lineage,
    transpose_lineage,
)
from repro.core.relation import LineageRelation


def _same_boxes(a, b):
    """Exact box-level equality (not just cell sets)."""
    ca = np.unique(np.concatenate([a.lo, a.hi], axis=1), axis=0)
    cb = np.unique(np.concatenate([b.lo, b.hi], axis=1), axis=0)
    return ca.shape == cb.shape and bool(np.array_equal(ca, cb))


def _compose_oracle(rels, cells, forward=True):
    """Walk uncompressed relations, propagating a cell set."""
    cur = {tuple(c) for c in cells}
    for rel in rels if forward else rels[::-1]:
        nxt = set()
        if forward:
            for o, i in zip(rel.out_idx, rel.in_idx):
                if tuple(i) in cur:
                    nxt.add(tuple(o))
        else:
            for o, i in zip(rel.out_idx, rel.in_idx):
                if tuple(o) in cur:
                    nxt.add(tuple(i))
        cur = nxt
    return cur


def test_image_like_workflow():
    """resize(subsample) -> brighten -> rotate -> flip -> aggregate:
    the paper's image workflow shape (Table VIII) at unit-test scale."""
    log = DSLog()
    H = W = 16
    names = ["img", "small", "bright", "rot", "flipped", "scores"]
    rels = [
        # subsample 2x (strided slice)
        LineageRelation(
            (H // 2, W // 2), (H, W),
            np.stack(np.meshgrid(np.arange(8), np.arange(8), indexing="ij"),
                     -1).reshape(-1, 2),
            np.stack(np.meshgrid(np.arange(0, 16, 2), np.arange(0, 16, 2),
                                 indexing="ij"), -1).reshape(-1, 2),
        ),
        identity_lineage((8, 8)),          # brighten
        transpose_lineage((8, 8), (1, 0)),  # rotate 90 (transpose part)
        flip_lineage((8, 8), 1),            # horizontal flip
        reduce_lineage((8, 8), 1),          # per-row score
    ]
    log.define_array(names[0], (H, W))
    for k, rel in enumerate(rels):
        log.define_array(names[k + 1], rel.out_shape)
        log.register_operation(
            f"op{k}", [names[k]], [names[k + 1]],
            capture=lambda r=rel: {(0, 0): r},
        )
    # forward: one source pixel -> which scores?
    src = np.array([[4, 6]])
    res = log.prov_query(names, src)
    got = res.cell_set()
    want = _compose_oracle(rels, src, forward=True)
    assert got == want
    # graph form (planner-routed) returns exactly the same boxes
    assert _same_boxes(res, log.prov_query(names[0], names[-1], src))
    # backward: one score -> contributing pixels
    back = np.array([[3]])
    resb = log.prov_query(names[::-1], back)
    gotb = resb.cell_set()
    wantb = _compose_oracle(rels, back, forward=False)
    assert gotb == wantb
    assert _same_boxes(resb, log.prov_query(names[-1], names[0], back))
    # compression actually engaged (at unit scale, serialization headers
    # dominate; the storage benchmark measures the real ratios at 1M cells)
    raw = sum(r.nbytes_raw() for r in rels)
    assert log.storage_bytes() < raw


def test_relational_workflow_join_groupby():
    """inner-join -> column math chain, as in the paper's relational flow."""
    log = DSLog()
    lk = np.array([0, 1, 2, 2, 5])
    rk = np.array([2, 2, 1, 9])
    rel_l, rel_r = inner_join_lineage(lk, rk, 2, 1)
    n_out = rel_l.out_shape[0]
    log.define_array("left", (5, 2))
    log.define_array("right", (4, 1))
    log.define_array("joined", rel_l.out_shape)
    log.register_operation(
        "inner_join", ["left", "right"], ["joined"],
        capture=lambda: {(0, 0): rel_l, (0, 1): rel_r},
        reuse=False,
    )
    rel_sum = reduce_lineage(rel_l.out_shape, 1)
    log.define_array("rowsum", (n_out,))
    log.register_operation(
        "add_cols", ["joined"], ["rowsum"], capture=lambda: {(0, 0): rel_sum}
    )
    # backward from one output row to both base tables
    q = np.array([[0]])
    res_left = log.prov_query(["rowsum", "joined", "left"], q)
    want_left = _compose_oracle([rel_l, rel_sum], q, forward=False)
    assert res_left.cell_set() == want_left
    assert _same_boxes(res_left, log.prov_query("rowsum", "left", q))
    res_right = log.prov_query(["rowsum", "joined", "right"], q)
    want_right = _compose_oracle([rel_r, rel_sum], q, forward=False)
    assert res_right.cell_set() == want_right
    assert _same_boxes(res_right, log.prov_query("rowsum", "right", q))
    # endpoint-set form answers both base tables from one plan
    both = log.prov_query("rowsum", ["left", "right"], q)
    assert both["left"].cell_set() == want_left
    assert both["right"].cell_set() == want_right


def test_resnet_like_block_lineage():
    """conv -> relu -> conv -> residual-add: ML-inference lineage (Fig 8C)."""
    log = DSLog()
    rel_c1 = conv2d_lineage(10, 10, 3, 3)
    rel_relu = identity_lineage((8, 8))
    rel_c2 = conv2d_lineage(8, 8, 3, 3)
    log.define_array("x", (10, 10))
    log.define_array("h1", (8, 8))
    log.define_array("h2", (8, 8))
    log.define_array("y", (6, 6))
    log.register_operation("conv1", ["x"], ["h1"], capture=lambda: {(0, 0): rel_c1})
    log.register_operation("relu", ["h1"], ["h2"], capture=lambda: {(0, 0): rel_relu})
    log.register_operation("conv2", ["h2"], ["y"], capture=lambda: {(0, 0): rel_c2})
    q = np.array([[2, 2]])
    res = log.prov_query(["y", "h2", "h1", "x"], q)
    got = res.cell_set()
    want = _compose_oracle([rel_c1, rel_relu, rel_c2], q, forward=False)
    assert got == want
    # receptive field of a 2-conv chain is 5x5
    assert len(got) == 25
    assert _same_boxes(res, log.prov_query("y", "x", q))


def test_jax_traced_function_lineage_end_to_end():
    """Capture lineage of an arbitrary jitted function via the jacobian
    oracle, store in DSLog, and query in situ."""
    import jax.numpy as jnp

    def f(x):
        h = jnp.tanh(x)
        return h.sum(axis=0)

    x = np.random.default_rng(0).random((4, 3)) + 0.5
    rel = capture_jacobian(f, x)[0]
    log = DSLog()
    log.define_array("in", (4, 3))
    log.define_array("out", (3,))
    log.register_operation("f", ["in"], ["out"], capture=lambda: {(0, 0): rel})
    got = log.prov_query(["out", "in"], np.array([[1]])).cell_set()
    assert got == {(i, 1) for i in range(4)}


def test_softmax_row_dependency_through_pipeline():
    log = DSLog()
    rel1 = softmax_lineage((4, 6), -1)
    rel2 = reduce_lineage((4, 6), 0)
    log.define_array("a", (4, 6))
    log.define_array("b", (4, 6))
    log.define_array("c", (6,))
    log.register_operation("softmax", ["a"], ["b"], capture=lambda: {(0, 0): rel1})
    log.register_operation("colsum", ["b"], ["c"], capture=lambda: {(0, 0): rel2})
    res = log.prov_query(["a", "b", "c"], np.array([[2, 0]]))
    assert res.cell_set() == {(j,) for j in range(6)}  # spreads across the row
    assert _same_boxes(res, log.prov_query("a", "c", np.array([[2, 0]])))
