"""``dsflow`` — the interprocedural lock/effect analysis (layer 3).

Each rule class is proven on a seeded fixture *positive* (a minimal module
tree that must produce exactly the expected finding) and its *negative* /
pragma'd twin (the same shape, correct or explicitly justified, which must
come back clean).  Fixture modules live under a ``core/`` directory so the
scope rules treat them like the real persistence layer, and the lock
tables are injected so the fixtures don't depend on the repo's ranks.

The suite also covers the repo-tree gate (``dsflow src/repro`` is clean —
every deliberate blocking site carries a justified pragma), the baseline
workflow, the shared finding schema, and the static↔dynamic cross-check
against ``racecheck``'s exported acquisition graph.
"""

import json
import os
import subprocess
import sys
import tempfile

import pytest

from repro.tools import dsflow, findings as findings_schema, racecheck

SRC = os.path.join(os.path.dirname(__file__), "..", "src", "repro")


def _write_tree(root, files: dict) -> list:
    """Write ``{relpath: source}`` under ``root`` and return the paths."""
    out = []
    for rel, src in files.items():
        path = os.path.join(root, rel)
        os.makedirs(os.path.dirname(path), exist_ok=True)
        with open(path, "w", encoding="utf-8") as f:
            f.write(src)
        out.append(path)
    return sorted(out)


def _analyze(files: dict, lock_order=None, static_locks=None, **kw):
    with tempfile.TemporaryDirectory() as d:
        paths = _write_tree(d, files)
        return dsflow.analyze_paths(
            paths, lock_order=lock_order, static_locks=static_locks, **kw
        )


def _rules(analysis) -> list:
    return [f.rule for f in analysis.findings]


# --------------------------------------------------------------------------- #
# rule: lock-order (transitive)
# --------------------------------------------------------------------------- #

_AB_ORDER = {"alpha._a": 10, "alpha._b": 20}
_AB_LOCKS = {
    ("alpha", "_a_lock"): "alpha._a",
    ("alpha", "_b_lock"): "alpha._b",
}


def _inversion_src(pragma: str = "") -> str:
    return f"""
class A:
    def outer(self):
        with self._b_lock:
            self.mid(){pragma}

    def mid(self):
        self.inner()

    def inner(self):
        with self._a_lock:
            pass
"""


def test_lock_order_inversion_two_calls_deep():
    a = _analyze(
        {"core/alpha.py": _inversion_src()},
        lock_order=_AB_ORDER,
        static_locks=_AB_LOCKS,
    )
    hits = [f for f in a.findings if f.rule == "lock-order"]
    assert len(hits) == 1, a.findings
    f = hits[0]
    assert "alpha._a (rank 10)" in f.message
    assert "alpha._b (rank 20)" in f.message
    # the chain names every hop, proving the finding is interprocedural
    assert "alpha.A.outer -> alpha.A.mid -> alpha.A.inner" in f.message


def test_lock_order_correct_nesting_is_clean():
    src = """
class A:
    def outer(self):
        with self._a_lock:
            self.inner()

    def inner(self):
        with self._b_lock:
            pass
"""
    a = _analyze(
        {"core/alpha.py": src},
        lock_order=_AB_ORDER,
        static_locks=_AB_LOCKS,
    )
    assert a.findings == []
    # ...but the edge itself is still in the graph for cycle/cross checks
    assert ("alpha._a", "alpha._b") in a.static_edges()


def test_lock_order_pragma_suppresses():
    a = _analyze(
        {"core/alpha.py": _inversion_src("  # dsflow: ignore[lock-order]")},
        lock_order=_AB_ORDER,
        static_locks=_AB_LOCKS,
    )
    assert "lock-order" not in _rules(a)


def test_lock_order_reentrant_self_edge_exempt():
    src = """
class A:
    def outer(self):
        with self._a_lock:
            self.outer()
"""
    a = _analyze(
        {"core/alpha.py": src},
        lock_order=_AB_ORDER,
        static_locks=_AB_LOCKS,
        reentrant={"alpha._a"},
    )
    assert "lock-order" not in _rules(a)
    # without the reentrant declaration the self-deadlock is a finding
    a2 = _analyze(
        {"core/alpha.py": src},
        lock_order=_AB_ORDER,
        static_locks=_AB_LOCKS,
    )
    assert "lock-order" in _rules(a2)


# --------------------------------------------------------------------------- #
# rule: lock-fsync (blocking I/O under a core lock, via a helper)
# --------------------------------------------------------------------------- #

_G_ORDER = {"gamma._g": 10}
_G_LOCKS = {("gamma", "_g_lock"): "gamma._g"}


def _fsync_src(pragma: str = "") -> str:
    return f"""
import os


class G:
    def flush(self):
        with self._g_lock:
            self._sync(){pragma}

    def _sync(self):
        os.fsync(self._fd)
"""


def test_lock_fsync_via_helper():
    a = _analyze(
        {"core/gamma.py": _fsync_src()},
        lock_order=_G_ORDER,
        static_locks=_G_LOCKS,
    )
    hits = [f for f in a.findings if f.rule == "lock-fsync"]
    assert len(hits) == 1, a.findings
    assert "fsync" in hits[0].message
    assert "gamma._g" in hits[0].message
    assert "gamma.G.flush -> gamma.G._sync" in hits[0].message


def test_lock_fsync_outside_lock_is_clean():
    src = """
import os


class G:
    def flush(self):
        with self._g_lock:
            fd = self._fd
        os.fsync(fd)
"""
    a = _analyze(
        {"core/gamma.py": src}, lock_order=_G_ORDER, static_locks=_G_LOCKS
    )
    assert a.findings == []


def test_lock_fsync_pragma_silences_the_cone():
    a = _analyze(
        {"core/gamma.py": _fsync_src("  # dsflow: ignore[lock-fsync]")},
        lock_order=_G_ORDER,
        static_locks=_G_LOCKS,
    )
    assert "lock-fsync" not in _rules(a)


def test_lock_fsync_exempt_lock_not_hot():
    # commit._flush_mutex semantics: a lock excluded from the hot set may
    # legitimately be held across blocking I/O
    a = _analyze(
        {"core/gamma.py": _fsync_src()},
        lock_order=_G_ORDER,
        static_locks=_G_LOCKS,
        hot_locks=set(),
    )
    assert "lock-fsync" not in _rules(a)


# --------------------------------------------------------------------------- #
# rule: wal-lease (unleased append reachable from a public entry)
# --------------------------------------------------------------------------- #

_WAL_FIXTURE = """
class WriteAheadLog:
    def __init__(self):
        self._records = []

    def append(self, rec):
        self._records.append(rec)
"""


def _store_src(body: str) -> dict:
    return {
        "core/wal.py": _WAL_FIXTURE,
        "core/store.py": "from .wal import WriteAheadLog\n\n\n"
        "class Store:\n"
        "    def __init__(self):\n"
        "        self.wal = WriteAheadLog()\n" + body,
    }


def test_wal_lease_unleased_public_entry():
    files = _store_src(
        """
    def put(self, rec):
        self._emit(rec)

    def _emit(self, rec):
        self.wal.append(rec)
"""
    )
    a = _analyze(files, lock_order={}, static_locks={})
    hits = [f for f in a.findings if f.rule == "wal-lease"]
    assert len(hits) == 1, a.findings
    f = hits[0]
    assert "store.Store.put" in f.message
    assert "wal-append" in f.message
    assert "store.Store._emit" in f.message  # the path is spelled out


def test_wal_lease_lease_checked_entry_is_clean():
    files = _store_src(
        """
    def put(self, rec):
        assert self._lease is not None, "writer lease required"
        self._emit(rec)

    def _emit(self, rec):
        assert self._lease is not None
        self.wal.append(rec)
"""
    )
    a = _analyze(files, lock_order={}, static_locks={})
    assert "wal-lease" not in _rules(a)


def test_wal_lease_pragma_at_append_site_silences_cone():
    files = _store_src(
        """
    def put(self, rec):
        self._emit(rec)

    def _emit(self, rec):
        self.wal.append(rec)  # dsflow: ignore[wal-lease]
"""
    )
    a = _analyze(files, lock_order={}, static_locks={})
    assert "wal-lease" not in _rules(a)


def test_wal_lease_private_entries_not_flagged():
    files = _store_src(
        """
    def _internal(self, rec):
        self.wal.append(rec)
"""
    )
    a = _analyze(files, lock_order={}, static_locks={})
    assert "wal-lease" not in _rules(a)


def test_wal_truncate_via_recover_literal():
    files = {
        "core/wal.py": _WAL_FIXTURE
        + """
    def recover(self, min_lsn=0, truncate=False):
        return list(self._records)
""",
        "core/store.py": "from .wal import WriteAheadLog\n\n\n"
        "class Store:\n"
        "    def __init__(self):\n"
        "        self.wal = WriteAheadLog()\n"
        "\n"
        "    def load(self):\n"
        "        return self.wal.recover(truncate=True)\n",
    }
    a = _analyze(files, lock_order={}, static_locks={})
    hits = [f for f in a.findings if f.rule == "wal-lease"]
    assert len(hits) == 1, a.findings
    assert "wal-truncate" in hits[0].message


# --------------------------------------------------------------------------- #
# rule: lock-cycle (cross-thread, unranked locks)
# --------------------------------------------------------------------------- #


def _cycle_src(b_first: str, b_second: str) -> str:
    return f"""
import threading


class D:
    def worker_a(self):
        with self._x_mutex:
            with self._y_mutex:
                pass

    def worker_b(self):
        with self.{b_first}:
            with self.{b_second}:
                pass

    def start(self):
        threading.Thread(target=self.worker_b).start()
        self.worker_a()
"""


def test_lock_cycle_across_threads():
    a = _analyze(
        {"core/delta.py": _cycle_src("_y_mutex", "_x_mutex")},
        lock_order={},
        static_locks={},
    )
    hits = [f for f in a.findings if f.rule == "lock-cycle"]
    assert len(hits) == 1, a.findings
    assert "delta._x_mutex" in hits[0].message
    assert "delta._y_mutex" in hits[0].message
    # unranked locks never produce rank findings, only the cycle
    assert "lock-order" not in _rules(a)


def test_lock_cycle_consistent_order_is_clean():
    a = _analyze(
        {"core/delta.py": _cycle_src("_x_mutex", "_y_mutex")},
        lock_order={},
        static_locks={},
    )
    assert a.findings == []


# --------------------------------------------------------------------------- #
# rule: registry-lock
# --------------------------------------------------------------------------- #


def _registry_src(guarded: bool) -> str:
    mut = "self._counters[name] = self._counters.get(name, 0) + n"
    body = (
        f"        with self._lock:\n            {mut}\n"
        if guarded
        else f"        {mut}\n"
    )
    return (
        "class MetricsRegistry:\n"
        "    def __init__(self):\n"
        "        self._counters = {}\n"
        "\n"
        "    def inc(self, name, n=1):\n" + body
    )


def test_registry_mutation_outside_lock():
    a = _analyze(
        {"core/metrics.py": _registry_src(guarded=False)},
        lock_order={"metrics._lock": 80},
        static_locks={("metrics", "_lock"): "metrics._lock"},
    )
    hits = [f for f in a.findings if f.rule == "registry-lock"]
    assert len(hits) == 1, a.findings
    assert "metrics.MetricsRegistry.inc" in hits[0].message


def test_registry_mutation_under_lock_is_clean():
    a = _analyze(
        {"core/metrics.py": _registry_src(guarded=True)},
        lock_order={"metrics._lock": 80},
        static_locks={("metrics", "_lock"): "metrics._lock"},
    )
    assert "registry-lock" not in _rules(a)


def test_registry_init_is_exempt():
    # the constructor mutates an object no other thread can see yet
    a = _analyze(
        {"core/metrics.py": _registry_src(guarded=True)},
        lock_order={"metrics._lock": 80},
        static_locks={("metrics", "_lock"): "metrics._lock"},
    )
    assert a.findings == []


# --------------------------------------------------------------------------- #
# the repo tree itself is clean (deliberate sites carry justified pragmas)
# --------------------------------------------------------------------------- #


def test_repo_tree_is_clean():
    a = dsflow.analyze_paths([SRC])
    assert a.findings == [], "\n".join(str(f) for f in a.findings)
    # sanity: the analysis actually saw the tree, not an empty dir
    assert a.stats["functions"] > 500
    assert len(a.static_edges()) >= 10


def test_repo_graph_covers_declared_nestings():
    """Spot-check edges the architecture mandates: the commit pipeline
    flushes the WAL under its mutex, and span exit reads metrics under the
    trace lock."""
    a = dsflow.analyze_paths([SRC])
    edges = a.static_edges()
    assert ("commit._flush_mutex", "wal._lock") in edges
    assert ("commit._flush_mutex", "commit._lock") in edges


# --------------------------------------------------------------------------- #
# static ↔ dynamic cross-check
# --------------------------------------------------------------------------- #


def test_check_dynamic_covered_edge_passes():
    a = dsflow.analyze_paths([SRC])
    held, acq = sorted(a.static_edges())[0]
    out = a.check_dynamic([{"held": held, "acquired": acq, "where": "t:1"}])
    assert out == []


def test_check_dynamic_uncovered_edge_fails():
    a = dsflow.analyze_paths([SRC])
    # reverse of a real edge: ranked on both ends, certainly not static
    out = a.check_dynamic(
        [{"held": "wal._lock", "acquired": "commit._flush_mutex",
          "where": "t:2"}]
    )
    assert [f.rule for f in out] == ["dynamic-uncovered"]
    assert "wal._lock -> commit._flush_mutex" in out[0].message


def test_check_dynamic_ignores_unranked_and_self_edges():
    a = dsflow.analyze_paths([SRC])
    out = a.check_dynamic(
        [
            {"held": "test._scratch_lock", "acquired": "wal._lock",
             "where": "t:3"},
            {"held": "wal._lock", "acquired": "wal._lock", "where": "t:4"},
        ]
    )
    assert out == []


def test_dynamic_workload_edges_covered_by_static_graph(
    race_detector, tmp_path
):
    """Close the loop with PR 6's dynamic detector: drive a real store
    under ``DSLOG_RACE_DETECT=1`` and assert every lock edge the runtime
    observed is present in the static call-graph's edge set."""
    from repro.core.capture import identity_lineage
    from repro.core.catalog import DSLog

    log = DSLog.open(str(tmp_path / "s"))
    log.add_lineage("A", "B", identity_lineage((6, 3)))
    log.commit()
    log.save()
    log.close()

    dyn = [
        {"held": h, "acquired": acq, "where": w}
        for (h, acq), w in racecheck.edges().items()
    ]
    assert dyn, "workload acquired no nested locks — instrumentation off?"
    a = dsflow.analyze_paths([SRC])
    missing = a.check_dynamic(dyn)
    assert missing == [], "\n".join(str(f) for f in missing)


def test_export_edges_merges_and_roundtrips(tmp_path):
    racecheck.reset()
    outer = racecheck.InstrumentedLock("views._lock")
    inner = racecheck.InstrumentedLock("table._lock")
    with outer:
        with inner:
            pass
    path = str(tmp_path / "edges.json")
    n = racecheck.export_edges(path)
    assert n == 1
    racecheck.reset()
    # a second export with fresh edges merges rather than overwrites
    a = racecheck.InstrumentedLock("wal._lock")
    b = racecheck.InstrumentedLock("catalog._stats_lock")
    with a:
        with b:
            pass
    assert racecheck.export_edges(path) == 2
    racecheck.reset()
    with open(path, encoding="utf-8") as f:
        data = json.load(f)
    pairs = {(e["held"], e["acquired"]) for e in data["edges"]}
    assert pairs == {
        ("views._lock", "table._lock"),
        ("wal._lock", "catalog._stats_lock"),
    }


# --------------------------------------------------------------------------- #
# shared finding schema + CLI surface
# --------------------------------------------------------------------------- #


def _run_cli(args, cwd=None):
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(
        os.path.dirname(__file__), "..", "src"
    )
    return subprocess.run(
        [sys.executable, "-m", "repro.tools.dsflow", *args],
        capture_output=True,
        text=True,
        env=env,
        cwd=cwd,
    )


def test_json_output_matches_shared_schema(tmp_path):
    paths = _write_tree(
        str(tmp_path),
        {"core/gamma.py": _fsync_src()},
    )
    # fixture lock tables are not injectable over the CLI, so exercise the
    # schema through the library surface instead, on the same fixture
    a = dsflow.analyze_paths(
        paths, lock_order=_G_ORDER, static_locks=_G_LOCKS
    )
    report = a.to_json()
    assert findings_schema.validate_findings(report["findings"]) == 1
    rec = report["findings"][0]
    assert rec["tool"] == "dsflow"
    assert rec["rule"] == "lock-fsync"
    assert rec["severity"] == "error"
    assert rec["line"] > 0


def test_fsck_json_matches_shared_schema(tmp_path):
    from repro.core.capture import identity_lineage
    from repro.core.catalog import DSLog
    from repro.tools.fsck import Report, fsck_store

    # a Report with findings emits shared-schema records
    rep = Report("r")
    rep.add("error", "blob-crc", "b_1.bin", "stored crc != computed")
    payload = rep.to_json()
    assert findings_schema.validate_findings(payload["findings"]) == 1
    rec = payload["findings"][0]
    assert rec == {
        "tool": "fsck",
        "rule": "blob-crc",
        "severity": "error",
        "path": "b_1.bin",
        "line": 0,
        "message": "stored crc != computed",
    }
    # ...and so does a real store scan (clean: the list validates empty)
    root = str(tmp_path / "s")
    log = DSLog(root=root)
    log.add_lineage("A", "B", identity_lineage((4, 2)))
    log.save()
    real = fsck_store(root).to_json()
    findings_schema.validate_findings(real["findings"])


def test_cli_exit_codes_and_baseline(tmp_path):
    fixture = tmp_path / "core"
    fixture.mkdir()
    # the CLI runs with the repo's real lock table: the module stems make
    # these locks wal._lock (rank 50) and views._lock (rank 15), so
    # acquiring the views lock inside the wal lock is a rank inversion
    (fixture / "views.py").write_text(
        "class V:\n"
        "    def grab(self):\n"
        "        with self._lock:\n"
        "            pass\n"
    )
    (fixture / "wal.py").write_text(
        "from .views import V\n"
        "\n"
        "\n"
        "class W:\n"
        "    def __init__(self):\n"
        "        self.v = V()\n"
        "\n"
        "    def bad(self):\n"
        "        with self._lock:\n"
        "            self.v.grab()\n"
    )
    r = _run_cli([str(tmp_path)])
    assert r.returncode == 1, r.stdout + r.stderr
    assert "lock-order" in r.stdout
    # record the baseline, then the same findings no longer fail
    baseline = tmp_path / "baseline.json"
    r2 = _run_cli([str(tmp_path), "--write-baseline", str(baseline)])
    assert r2.returncode == 1
    r3 = _run_cli([str(tmp_path), "--baseline", str(baseline)])
    assert r3.returncode == 0, r3.stdout + r3.stderr
    # the real tree is clean against an empty baseline
    r4 = _run_cli([SRC])
    assert r4.returncode == 0, r4.stdout + r4.stderr


def test_cli_check_dynamic(tmp_path):
    edges = tmp_path / "edges.json"
    edges.write_text(
        json.dumps(
            {
                "edges": [
                    {
                        "held": "wal._lock",
                        "acquired": "commit._flush_mutex",
                        "where": "t:9",
                    }
                ]
            }
        )
    )
    r = _run_cli([SRC, "--check-dynamic", str(edges)])
    assert r.returncode == 1
    assert "dynamic-uncovered" in r.stdout
    good = tmp_path / "good.json"
    good.write_text(json.dumps({"edges": []}))
    r2 = _run_cli([SRC, "--check-dynamic", str(good)])
    assert r2.returncode == 0, r2.stdout + r2.stderr


def test_repo_baseline_file_is_current():
    """`tools/dsflow_baseline.json` (what CI diffs against) stays in sync:
    the tree has no findings, so the baseline must be empty too."""
    path = os.path.join(
        os.path.dirname(__file__), "..", "src", "repro", "tools",
        "dsflow_baseline.json",
    )
    assert os.path.exists(path), "baseline file missing"
    known = dsflow.load_baseline(path)
    assert known == set(), "baseline holds stale findings; regenerate with "
    "--write-baseline"


def test_readme_lock_table_matches_lockorder():
    """The README's lock-rank table (between the ``lockorder:begin/end``
    markers) is generated from ``lockorder.markdown_table()`` — regenerate
    with ``python -m repro.tools.lockorder --markdown`` if this fails."""
    from repro.tools import lockorder

    readme = os.path.join(os.path.dirname(__file__), "..", "README.md")
    text = open(readme).read()
    begin, end = "<!-- lockorder:begin -->", "<!-- lockorder:end -->"
    assert begin in text and end in text, "README lost its lockorder markers"
    embedded = text.split(begin, 1)[1].split(end, 1)[0].strip()
    assert embedded == lockorder.markdown_table(), (
        "README lock table drifted from tools/lockorder.py"
    )
