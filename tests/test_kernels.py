"""Pallas kernels vs pure-jnp refs: shape/dtype sweeps, interpret=True."""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.kernels.ops import range_join_pairs, run_boundaries
from repro.kernels.range_join import range_join_mask
from repro.kernels.ref import range_join_mask_ref, run_boundaries_ref
from repro.kernels.run_boundary import run_boundaries_packed

rng = np.random.default_rng(0)


@pytest.mark.parametrize("n,nk,block", [
    (512, 1, 128), (1024, 2, 256), (2048, 4, 512), (4096, 8, 1024),
    (1024, 1, 1024), (3072, 6, 256),
])
def test_run_boundary_matches_ref(n, nk, block):
    packed = np.zeros((n, 128), np.int32)
    for c in range(nk):
        packed[:, c] = np.sort(rng.integers(0, 7, n))
    lo = np.sort(rng.integers(0, n // 2, n))
    packed[:, nk] = lo
    packed[:, nk + 1] = lo + rng.integers(0, 3, n)
    got = run_boundaries_packed(
        jnp.asarray(packed), n_keys=nk, block_rows=block, interpret=True
    )
    want = run_boundaries_ref(jnp.asarray(packed), nk)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@settings(max_examples=25, deadline=None)
@given(data=st.data())
def test_run_boundary_property(data):
    n = data.draw(st.sampled_from([256, 512, 1024]))
    nk = data.draw(st.integers(1, 5))
    seed = data.draw(st.integers(0, 2**31))
    r = np.random.default_rng(seed)
    packed = np.zeros((n, 128), np.int32)
    for c in range(nk):
        packed[:, c] = np.sort(r.integers(0, 5, n))
    lo = np.sort(r.integers(0, 40, n))
    packed[:, nk] = lo
    packed[:, nk + 1] = lo
    got = np.asarray(
        run_boundaries_packed(jnp.asarray(packed), n_keys=nk, block_rows=256, interpret=True)
    )
    want = np.asarray(run_boundaries_ref(jnp.asarray(packed), nk))
    np.testing.assert_array_equal(got, want)


def test_run_boundaries_wrapper_vs_numpy():
    """Wrapper output drives the same segmentation numpy produces."""
    n = 3000
    g = np.sort(rng.integers(0, 12, n)).astype(np.int64)
    lo = rng.integers(0, 50, n).astype(np.int64)
    order = np.lexsort((lo, g))
    g, lo = g[order], lo[order]
    flags = run_boundaries([g], lo, lo, block_rows=512)
    want = np.ones(n, bool)
    want[1:] = (g[1:] != g[:-1]) | (lo[1:] > lo[:-1] + 1)
    np.testing.assert_array_equal(flags, want)


@pytest.mark.parametrize("nq,nr,l,bq,br", [
    (100, 300, 1, 128, 128), (257, 511, 2, 128, 256),
    (64, 64, 3, 64, 64), (1000, 50, 4, 256, 128),
])
def test_range_join_matches_oracle(nq, nr, l, bq, br):
    q_lo = rng.integers(0, 60, (nq, l))
    q_hi = q_lo + rng.integers(0, 6, (nq, l))
    r_lo = rng.integers(0, 60, (nr, l))
    r_hi = r_lo + rng.integers(0, 6, (nr, l))
    qi, ri = range_join_pairs(q_lo, q_hi, r_lo, r_hi, block_q=bq, block_r=br)
    ov = np.ones((nq, nr), bool)
    for j in range(l):
        ov &= (q_lo[:, j : j + 1] <= r_hi[None, :, j]) & (
            r_lo[None, :, j] <= q_hi[:, j : j + 1]
        )
    wq, wr = np.nonzero(ov)
    np.testing.assert_array_equal(qi, wq)
    np.testing.assert_array_equal(ri, wr)


def test_range_join_kernel_vs_ref_padded():
    nq = nr = 256
    l = 2
    q = np.zeros((nq, 128), np.int32)
    r = np.zeros((nr, 128), np.int32)
    q[:, :l] = rng.integers(0, 30, (nq, l))
    q[:, l : 2 * l] = q[:, :l] + rng.integers(0, 4, (nq, l))
    r[:, :l] = rng.integers(0, 30, (nr, l))
    r[:, l : 2 * l] = r[:, :l] + rng.integers(0, 4, (nr, l))
    got = range_join_mask(
        jnp.asarray(q), jnp.asarray(r), n_attrs=l, block_q=128, block_r=128,
        interpret=True,
    )
    want = range_join_mask_ref(jnp.asarray(q), jnp.asarray(r), l)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_range_join_empty_inputs():
    qi, ri = range_join_pairs(
        np.zeros((0, 2)), np.zeros((0, 2)), np.zeros((5, 2)), np.ones((5, 2))
    )
    assert qi.size == 0 and ri.size == 0


@pytest.mark.parametrize("nq", [255, 256, 257])
@pytest.mark.parametrize("nr", [255, 256, 257])
def test_range_join_internal_padding_at_block_boundaries(nq, nr):
    """Regression (ISSUE 5): the kernel pads internally — row counts that
    are not block multiples must work and padded rows must never match."""
    l = 2
    q_lo = rng.integers(0, 40, (nq, l))
    q_hi = q_lo + rng.integers(0, 5, (nq, l))
    r_lo = rng.integers(0, 40, (nr, l))
    r_hi = r_lo + rng.integers(0, 5, (nr, l))
    qi, ri = range_join_pairs(q_lo, q_hi, r_lo, r_hi, block_q=256, block_r=256)
    ov = np.ones((nq, nr), bool)
    for j in range(l):
        ov &= (q_lo[:, j : j + 1] <= r_hi[None, :, j]) & (
            r_lo[None, :, j] <= q_hi[:, j : j + 1]
        )
    wq, wr = np.nonzero(ov)
    np.testing.assert_array_equal(qi, wq)
    np.testing.assert_array_equal(ri, wr)


def test_range_join_mask_unpadded_rows_direct():
    """range_join_mask itself accepts non-multiple row counts (the old
    ``nq % block_q == 0`` assert forced callers to pre-pad)."""
    q = np.zeros((255, 128), np.int32)
    r = np.zeros((130, 128), np.int32)
    q[:, :1] = rng.integers(0, 9, (255, 1))
    q[:, 1:2] = q[:, :1] + 1
    r[:, :1] = rng.integers(0, 9, (130, 1))
    r[:, 1:2] = r[:, :1] + 1
    mask = range_join_mask(
        jnp.asarray(q), jnp.asarray(r), n_attrs=1, block_q=128, block_r=128,
        interpret=True,
    )
    assert mask.shape == (255, 130)
    want = (q[:, :1] <= r[None, :, 1]) & (r[None, :, 0] <= q[:, 1:2])
    np.testing.assert_array_equal(np.asarray(mask).astype(bool), want)


def test_range_join_mask_lane_capacity_raises():
    q = np.zeros((8, 128), np.int32)
    with pytest.raises(ValueError, match="lane capacity"):
        range_join_mask(
            jnp.asarray(q), jnp.asarray(q), n_attrs=65, interpret=True
        )


def test_segmented_pack_matches_per_segment_joins():
    """One launch, many joins: segment-id lanes keep the masks separable,
    mixed attribute widths ride the same pack."""
    from repro.kernels.ops import segmented_range_join_pairs

    segs = []
    for l in (1, 3, 2, 1):
        nq, nr = int(rng.integers(1, 50)), int(rng.integers(1, 70))
        q_lo = rng.integers(0, 25, (nq, l))
        q_hi = q_lo + rng.integers(0, 5, (nq, l))
        r_lo = rng.integers(0, 25, (nr, l))
        r_hi = r_lo + rng.integers(0, 5, (nr, l))
        segs.append((q_lo, q_hi, r_lo, r_hi))
    got, info = segmented_range_join_pairs(
        segs, block_q=64, block_r=64, interpret=True
    )
    assert info["launches"] == 1 and info["rows_padded"] >= info["rows"] > 0
    for (q_lo, q_hi, r_lo, r_hi), (qi, ri) in zip(segs, got):
        wq, wr = range_join_pairs(q_lo, q_hi, r_lo, r_hi, block_q=64, block_r=64)
        np.testing.assert_array_equal(qi, wq)
        np.testing.assert_array_equal(ri, wr)


def _random_segments(r, k, widths=(1, 2, 3), max_rows=90, coords=(0, 25)):
    segs = []
    for i in range(k):
        l = int(widths[i % len(widths)])
        nq, nr = int(r.integers(1, max_rows)), int(r.integers(1, max_rows))
        q_lo = r.integers(*coords, (nq, l))
        q_hi = q_lo + r.integers(0, 5, (nq, l))
        r_lo = r.integers(*coords, (nr, l))
        r_hi = r_lo + r.integers(0, 5, (nr, l))
        segs.append((q_lo, q_hi, r_lo, r_hi))
    return segs


@settings(max_examples=20, deadline=None)
@given(data=st.data())
def test_blockdiag_layout_property(data):
    """ISSUE 8 tentpole: the block-diagonal tile schedule is bit-identical
    to the masked cross-product launch and the per-segment oracle, across
    ragged segment counts/sizes/widths, and never visits more tiles than
    the cross product."""
    from repro.kernels.ops import segmented_range_join_pairs

    seed = data.draw(st.integers(0, 2**31))
    k = data.draw(st.integers(2, 7))
    bq = data.draw(st.sampled_from([32, 64, 128]))
    br = data.draw(st.sampled_from([32, 64, 128]))
    segs = _random_segments(np.random.default_rng(seed), k)
    dense, dinfo = segmented_range_join_pairs(
        segs, block_q=bq, block_r=br, interpret=True, layout="dense"
    )
    diag, ginfo = segmented_range_join_pairs(
        segs, block_q=bq, block_r=br, interpret=True, layout="blockdiag"
    )
    assert ginfo["layout"] == "blockdiag" and dinfo["layout"] == "dense"
    assert ginfo["tiles_visited"] + ginfo["tiles_skipped"] >= dinfo["tiles_visited"]
    for s, (q_lo, q_hi, r_lo, r_hi) in enumerate(segs):
        wq, wr = range_join_pairs(q_lo, q_hi, r_lo, r_hi, block_q=bq, block_r=br)
        for got in (dense[s], diag[s]):
            np.testing.assert_array_equal(got[0], wq)
            np.testing.assert_array_equal(got[1], wr)


def test_blockdiag_padding_rows_never_match():
    """Per-segment padding rows carry (lo=1, hi=0); boxes spanning [<=0, >=1]
    can graze them, so the extractor's bounds filter must drop any pair
    touching a padded row."""
    from repro.kernels.ops import segmented_range_join_pairs

    segs = []
    for _ in range(3):
        nq, nr = int(rng.integers(3, 40)), int(rng.integers(3, 40))
        q_lo = rng.integers(-4, 2, (nq, 2))  # spans the pad sentinel [1, 0]
        q_hi = q_lo + rng.integers(0, 6, (nq, 2))
        r_lo = rng.integers(-4, 2, (nr, 2))
        r_hi = r_lo + rng.integers(0, 6, (nr, 2))
        segs.append((q_lo, q_hi, r_lo, r_hi))
    diag, _ = segmented_range_join_pairs(
        segs, block_q=32, block_r=32, interpret=True, layout="blockdiag"
    )
    for (q_lo, q_hi, r_lo, r_hi), (qi, ri) in zip(segs, diag):
        wq, wr = range_join_pairs(q_lo, q_hi, r_lo, r_hi)
        np.testing.assert_array_equal(qi, wq)
        np.testing.assert_array_equal(ri, wr)


def test_segmented_auto_layout_routing():
    """layout="auto" charges both schedules in tiles: a many-segment
    frontier goes block-diagonal, one segment stays on the dense launch."""
    from repro.kernels.ops import segmented_range_join_pairs

    segs = _random_segments(np.random.default_rng(3), 6, max_rows=200)
    _, info = segmented_range_join_pairs(segs, block_q=64, block_r=64,
                                         interpret=True, layout="auto")
    assert info["layout"] == "blockdiag"
    assert info["tiles_skipped"] > 0
    _, info1 = segmented_range_join_pairs(segs[:1], block_q=64, block_r=64,
                                          interpret=True, layout="auto")
    assert info1["layout"] == "dense" and info1["tiles_skipped"] == 0
    with pytest.raises(ValueError, match="layout"):
        segmented_range_join_pairs(segs, layout="ragged")


def test_segmented_single_segment_skips_id_lane():
    """ISSUE 8 satellite: a one-segment frontier needs no segment-id lane,
    so the max packable width is LANES // 2 — one more than the segmented
    pack admits."""
    from repro.kernels.ops import segmented_range_join_pairs
    from repro.kernels.range_join import LANES

    l = LANES // 2  # 64: lo+hi fill all 128 lanes, no room for a seg id
    box = (np.zeros((4, l)), np.ones((4, l)), np.zeros((5, l)), np.ones((5, l)))
    got, info = segmented_range_join_pairs([box], interpret=True)
    assert info["layout"] == "dense"
    assert got[0][0].size == 4 * 5  # unit boxes all overlap
    with pytest.raises(ValueError, match="lane capacity"):
        segmented_range_join_pairs([box, box], interpret=True, layout="dense")


@pytest.mark.parametrize("n", [1, 255, 1024, 1025])
def test_run_boundary_pads_non_multiple_rows(n):
    """Regression (ISSUE 8): run_boundaries_packed padded internally
    instead of asserting ``n % block_rows == 0``."""
    r = np.random.default_rng(n)
    packed = np.zeros((n, 128), np.int32)
    packed[:, 0] = np.sort(r.integers(0, 6, n))
    lo = np.sort(r.integers(0, max(n // 3, 2), n))
    packed[:, 1] = lo
    packed[:, 2] = lo + r.integers(0, 3, n)
    got = run_boundaries_packed(
        jnp.asarray(packed), n_keys=1, block_rows=256, interpret=True
    )
    assert got.shape == (n,)
    want = run_boundaries_ref(jnp.asarray(packed), 1)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
