"""Cost-based planner: graph-form prov_query == path form == row oracle."""

import numpy as np
import pytest

from repro.core.capture import (
    flip_lineage,
    identity_lineage,
    reduce_lineage,
    roll_lineage,
    transpose_lineage,
)
from repro.core.catalog import DSLog
from repro.core.query import QueryBox


def _propagate(rel, cells, forward=True):
    cur = {tuple(c) for c in cells}
    nxt = set()
    for o, i in zip(rel.out_idx, rel.in_idx):
        if forward and tuple(i) in cur:
            nxt.add(tuple(o))
        if not forward and tuple(o) in cur:
            nxt.add(tuple(i))
    return nxt


def _dag_oracle(log, rels, src, dst, cells, forward=True):
    """Propagate a cell set through the DAG of uncompressed relations.

    ``rels`` maps (src_array, dst_array) -> [LineageRelation, ...].
    """
    topo = log.graph.topo_order()
    order = topo if forward else topo[::-1]
    influence = {n: set() for n in topo}
    influence[src] = {tuple(c) for c in cells}
    for node in order:
        for (u, v), rlist in rels.items():
            edge_from = u if forward else v
            if edge_from != node:
                continue
            out = v if forward else u
            for rel in rlist:
                influence[out] |= _propagate(rel, influence[node], forward)
    return influence[dst]


def _linear_chain(log):
    """img -> small -> rot -> scores, mixed op kinds."""
    rels = [
        identity_lineage((8, 8)),
        transpose_lineage((8, 8), (1, 0)),
        reduce_lineage((8, 8), 1),
    ]
    names = ["img", "small", "rot", "scores"]
    log.define_array(names[0], (8, 8))
    for k, rel in enumerate(rels):
        log.define_array(names[k + 1], rel.out_shape)
        log.register_operation(
            f"op{k}", [names[k]], [names[k + 1]],
            capture=lambda r=rel: {(0, 0): r}, reuse=False,
        )
    return names, rels


def _boxes_equal(a: QueryBox, b: QueryBox) -> bool:
    ca = np.unique(np.concatenate([a.lo, a.hi], axis=1), axis=0)
    cb = np.unique(np.concatenate([b.lo, b.hi], axis=1), axis=0)
    return ca.shape == cb.shape and bool(np.array_equal(ca, cb))


def test_graph_form_matches_path_form_linear():
    log = DSLog()
    names, _ = _linear_chain(log)
    cells = np.array([[2, 3], [5, 1]])
    for merge in (True, False):
        via_path = log.prov_query(names, cells, merge=merge)
        via_graph = log.prov_query(names[0], names[-1], cells, merge=merge)
        assert _boxes_equal(via_path, via_graph)
        back = np.array([[4]])
        bp = log.prov_query(names[::-1], back, merge=merge)
        bg = log.prov_query(names[-1], names[0], back, merge=merge)
        assert _boxes_equal(bp, bg)


def test_graph_form_batch_matches_path_form():
    log = DSLog()
    names, _ = _linear_chain(log)
    queries = [np.array([[1, 1]]), np.array([[2, 3], [5, 1]]), np.array([[1, 1]])]
    via_path = log.prov_query_batch(names, queries)
    via_graph = log.prov_query_batch(names[0], names[-1], queries)
    assert len(via_path) == len(via_graph) == 3
    for p, g in zip(via_path, via_graph):
        assert _boxes_equal(p, g)
    assert log.prov_query_batch(names[0], names[-1], []) == []


def _diamond(log, side=8):
    """x fans out to a and b (one 2-output op), which fan back into z."""
    rel_xa = flip_lineage((side, side), 0)
    rel_xb = roll_lineage((side, side), 2, 1)
    rel_az = identity_lineage((side, side))
    rel_bz = identity_lineage((side, side))
    log.define_array("x", (side, side))
    log.define_array("a", (side, side))
    log.define_array("b", (side, side))
    log.define_array("z", (side, side))
    log.register_operation(
        "split", ["x"], ["a", "b"],
        capture=lambda: {(0, 0): rel_xa, (1, 0): rel_xb}, reuse=False,
    )
    log.register_operation(
        "combine", ["a", "b"], ["z"],
        capture=lambda: {(0, 0): rel_az, (0, 1): rel_bz}, reuse=False,
    )
    return {
        ("x", "a"): [rel_xa],
        ("x", "b"): [rel_xb],
        ("a", "z"): [rel_az],
        ("b", "z"): [rel_bz],
    }


def test_diamond_dag_matches_row_oracle():
    """Fan-out then fan-in: planner result == uncompressed-row propagation."""
    log = DSLog()
    rels = _diamond(log)
    cells = np.array([[2, 3], [7, 0]])
    fwd = log.prov_query("x", "z", cells)
    assert fwd.cell_set() == _dag_oracle(log, rels, "x", "z", cells, forward=True)
    back = np.array([[4, 4]])
    bwd = log.prov_query("z", "x", back)
    assert bwd.cell_set() == _dag_oracle(log, rels, "z", "x", back, forward=False)


def test_diamond_equals_per_path_union():
    """Planner-merged execution covers exactly the union over simple paths."""
    log = DSLog()
    _diamond(log)
    cells = np.array([[1, 5]])
    merged = log.prov_query("x", "z", cells).cell_set()
    paths = log.graph.simple_paths("x", "z")
    assert sorted(paths) == [["x", "a", "z"], ["x", "b", "z"]]
    union = set()
    for p in paths:
        union |= log.prov_query(p, cells).cell_set()
    assert merged == union


def test_fanin_frontier_is_merged():
    """At the fan-in array the planner deduplicates the combined frontier:
    identical branch contributions collapse to one box set."""
    log = DSLog()
    # both branches are identity -> contributions at z coincide exactly
    log.define_array("x", (6, 6))
    log.define_array("a", (6, 6))
    log.define_array("b", (6, 6))
    log.define_array("z", (6, 6))
    ident = lambda: identity_lineage((6, 6))
    log.register_operation("p", ["x"], ["a"], capture=lambda: {(0, 0): ident()}, reuse=False)
    log.register_operation("q", ["x"], ["b"], capture=lambda: {(0, 0): ident()}, reuse=False)
    log.register_operation(
        "combine", ["a", "b"], ["z"],
        capture=lambda: {(0, 0): ident(), (0, 1): ident()}, reuse=False,
    )
    cells = np.array([[2, 2]])
    plan = log.planner.plan("x", ["z"])
    out = log.planner.execute(plan, log._as_boxes("x", [cells]), collect="all")
    assert out["z"][0].n_rows == 1  # merged, not 2 copies of the same box
    assert out["z"][0].cell_set() == {(2, 2)}


def test_planner_materialization_choice():
    """Forward traversal without a stored forward table must run the inverse
    join on the backward table; with one stored, the natural join wins."""
    log_nofwd = DSLog(store_forward=False)
    log_fwd = DSLog(store_forward=True)
    rel = reduce_lineage((8, 4), 1)
    for log in (log_nofwd, log_fwd):
        log.add_lineage("in", "out", rel)
    q = np.array([[3, 2]])
    plan_no = log_nofwd.planner.plan("in", ["out"])
    (step,) = plan_no.steps[plan_no.order[-1]]
    assert step.choices[0].stored == "backward"
    assert step.choices[0].frontier_on == "value"  # inverse join
    plan_f = log_fwd.planner.plan("in", ["out"])
    (step,) = plan_f.steps[plan_f.order[-1]]
    assert step.choices[0].stored == "forward"
    assert step.choices[0].frontier_on == "key"  # natural join
    # both produce identical answers
    assert (
        log_nofwd.prov_query("in", "out", q).cell_set()
        == log_fwd.prov_query("in", "out", q).cell_set()
        == {(3,)}
    )


def test_multi_target_query_returns_dict():
    log = DSLog()
    _diamond(log)
    cells = np.array([[0, 0]])
    res = log.prov_query("x", ["a", "z"], cells)
    assert set(res) == {"a", "z"}
    assert res["a"].cell_set() == log.prov_query("x", "a", cells).cell_set()
    assert res["z"].cell_set() == log.prov_query("x", "z", cells).cell_set()


def test_no_route_and_bad_args_raise():
    log = DSLog()
    log.add_lineage("u", "v", identity_lineage((4,)))
    log.add_lineage("p", "q", identity_lineage((4,)))
    with pytest.raises(KeyError):
        log.prov_query("u", "q", np.array([[1]]))
    with pytest.raises(KeyError):
        log.prov_query("u", "nope", np.array([[1]]))
    with pytest.raises(ValueError):
        log.planner.plan("u", ["u"])
    with pytest.raises(TypeError):
        log.prov_query("u", np.array([[1]]))  # missing dst
    with pytest.raises(TypeError):
        log.prov_query("u", "v", np.array([[1]]), "extra")


def test_legacy_positional_merge_still_accepted():
    """Pre-graph signature was prov_query(path, cells, merge) — keep it."""
    log = DSLog()
    names, _ = _linear_chain(log)
    cells = np.array([[2, 3], [5, 1]])
    pos = log.prov_query(names, cells, False)
    kw = log.prov_query(names, cells, merge=False)
    assert _boxes_equal(pos, kw)
    batch = log.prov_query_batch(names, [cells], False)
    assert _boxes_equal(batch[0], kw)


def test_execute_validates_dict_query_batches():
    log = DSLog()
    _diamond(log)
    # a plan whose starts are the two branch arrays
    plan = log.planner.plan("z", ["a", "b"])  # backward: frontier on z
    qs = log._as_boxes("z", [np.array([[1, 1]])])
    out = log.planner.execute(plan, qs)
    assert set(out) == {"a", "b"}
    # multi-start plans demand per-start batches with exact name coverage
    multi = log.planner.plan({"a", "b"}, ["z"])
    qa = log._as_boxes("a", [np.array([[1, 1]])])
    qb = log._as_boxes("b", [np.array([[1, 1]])])
    with pytest.raises(KeyError):
        log.planner.execute(multi, {"a": qa, "bogus": qb})
    with pytest.raises(ValueError):
        log.planner.execute(multi, {"a": qa})  # 'b' batch missing
    res = log.planner.execute(multi, {"a": qa, "b": qb})
    assert res["z"][0].n_cells() >= 1


def test_plan_describe_smoke():
    log = DSLog()
    _diamond(log)
    plan = log.planner.plan("x", ["z"])
    text = plan.describe()
    assert "forward plan" in text and "x -> " in text
    back = log.planner.plan("z", ["x"])
    assert back.direction == "backward"
