"""Optimizer, gradient compression, data pipeline, checkpoint manager."""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint.manager import CheckpointManager
from repro.data.pipeline import PipelineConfig, TokenPipeline
from repro.optim.adamw import AdamWConfig, adamw_init, adamw_update, cosine_schedule
from repro.optim.compress import (
    ef_roundtrip,
    int8_compress,
    int8_decompress,
    topk_compress,
    topk_decompress,
)


# ----------------------------- optimizer ------------------------------ #
def test_adamw_minimizes_quadratic():
    cfg = AdamWConfig(lr=0.1, weight_decay=0.0, warmup_steps=0, schedule="constant")
    params = {"w": jnp.array([3.0, -2.0])}
    state = adamw_init(params)
    loss = lambda p: jnp.sum(p["w"] ** 2)
    for _ in range(200):
        g = jax.grad(loss)(params)
        params, state, _ = adamw_update(params, g, state, cfg)
    assert float(loss(params)) < 1e-3
    assert int(state["step"]) == 200


def test_grad_clip_bounds_update():
    cfg = AdamWConfig(lr=1.0, clip_norm=1.0, weight_decay=0.0, warmup_steps=0,
                      schedule="constant")
    params = {"w": jnp.zeros(3)}
    state = adamw_init(params)
    g = {"w": jnp.array([1e6, 0.0, 0.0])}
    _, state, metrics = adamw_update(params, g, state, cfg)
    assert float(metrics["grad_norm"]) > 1e5  # reported unclipped
    assert float(jnp.abs(state["m"]["w"]).max()) <= 1.0 + 1e-5  # clipped inside


def test_schedule_shapes():
    cfg = AdamWConfig(lr=1.0, warmup_steps=10, total_steps=100)
    assert float(cosine_schedule(jnp.float32(0.0), cfg)) == 0.0
    assert abs(float(cosine_schedule(jnp.float32(10.0), cfg)) - 1.0) < 1e-6
    assert float(cosine_schedule(jnp.float32(100.0), cfg)) < 1e-6


# ------------------------ gradient compression ------------------------ #
def test_int8_roundtrip_bounded_error():
    x = jnp.asarray(np.random.default_rng(0).normal(size=512).astype(np.float32))
    q, s = int8_compress(x)
    err = np.abs(np.asarray(int8_decompress(q, s) - x))
    assert err.max() <= float(s) / 2 + 1e-6


def test_topk_keeps_largest():
    x = jnp.asarray([0.1, -5.0, 0.2, 4.0])
    kept, idx, shape = topk_compress(x, 0.5)
    back = topk_decompress(kept, idx, shape)
    np.testing.assert_allclose(np.asarray(back), [0.0, -5.0, 0.0, 4.0])


def test_error_feedback_unbiased_over_time():
    """EF compensates: the *sum* of emitted approximations tracks the sum of
    true gradients (bounded residual)."""
    rng = np.random.default_rng(1)
    err = jnp.zeros(64)
    total_true = np.zeros(64)
    total_sent = np.zeros(64)
    for _ in range(50):
        g = jnp.asarray(rng.normal(size=64).astype(np.float32)) * 0.01
        sent, err = ef_roundtrip(g, err, scheme="topk", frac=0.1)
        total_true += np.asarray(g)
        total_sent += np.asarray(sent)
    resid = np.abs(total_true - total_sent).max()
    assert resid < 0.05  # residual stays bounded, not accumulating


# ------------------------------ pipeline ------------------------------ #
def test_pipeline_deterministic_and_elastic():
    cfg = PipelineConfig(vocab=1000, seq_len=32, global_batch=8, seed=7)
    full = TokenPipeline(cfg, data_shards=1, shard_id=0)
    g0 = full.global_batch_tokens(0)
    # identical global stream regardless of sharding (elasticity invariant)
    shards = [TokenPipeline(cfg, data_shards=4, shard_id=k) for k in range(4)]
    parts = np.concatenate([s.shard_slice(0) for s in shards], axis=0)
    np.testing.assert_array_equal(g0, parts)
    # deterministic across instances
    again = TokenPipeline(cfg, data_shards=1, shard_id=0).global_batch_tokens(0)
    np.testing.assert_array_equal(g0, again)
    # different steps differ
    assert not np.array_equal(g0, full.global_batch_tokens(1))


def test_pipeline_state_roundtrip():
    cfg = PipelineConfig(vocab=100, seq_len=8, global_batch=4)
    p = TokenPipeline(cfg)
    p.next_batch()
    p.next_batch()
    state = p.state_dict()
    q = TokenPipeline(cfg)
    q.load_state_dict(state)
    np.testing.assert_array_equal(p.next_batch()["tokens"], q.next_batch()["tokens"])


def test_pipeline_lineage_logged_and_queryable():
    from repro.core.catalog import DSLog

    log = DSLog()
    cfg = PipelineConfig(vocab=100, seq_len=8, global_batch=4, n_source_rows=64)
    p = TokenPipeline(cfg, data_shards=2, shard_id=0, dslog=log)
    p.next_batch()
    rows = p.source_rows_for_step(0)
    # backward: shard row 1 of shard 0 came from global batch row 1 = doc rows[1]
    res = log.prov_query(["shard_s0_k0", "batch_s0", "corpus"], np.array([[1, 3]]))
    assert res.cell_set() == {(int(rows[1]), 3)}
    # reuse: second step's shard_slice should hit dim_sig after confirmation
    p.next_batch()
    p.next_batch()
    reused = [op.reused for op in log.ops if op.op_name == "shard_slice"]
    assert reused[-1] == "dim"


# ----------------------------- checkpoint ----------------------------- #
def test_checkpoint_roundtrip_and_gc(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2)
    tree = {"a": jnp.arange(6, dtype=jnp.float32).reshape(2, 3),
            "b": {"c": jnp.ones(4, jnp.bfloat16)}}
    for step in (1, 2, 3):
        mgr.save(step, tree, extra={"step": step})
    assert mgr.latest_step() == 3
    got, extra = mgr.restore()
    np.testing.assert_array_equal(np.asarray(got["a"]), np.asarray(tree["a"]))
    assert got["b"]["c"].dtype == jnp.bfloat16
    assert extra["step"] == 3
    dirs = [d for d in os.listdir(tmp_path) if d.startswith("step_")]
    assert len(dirs) == 2  # keep=2 GC'd step_1


def test_checkpoint_async_and_pointer_atomicity(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=3, async_save=True)
    mgr.save(5, {"x": jnp.zeros(3)}, extra={"step": 5})
    mgr.wait()
    assert mgr.latest_step() == 5
    assert not any(d.endswith(".tmp") for d in os.listdir(tmp_path))


def test_watchdog_fires_on_straggler():
    import time

    from repro.distributed.elastic import StepWatchdog

    w = StepWatchdog(factor=1.0, floor_s=0.05)
    for _ in range(5):
        w.guard(lambda: time.sleep(0.01))
    fired = []
    w.guard(lambda: time.sleep(0.5), on_straggler=lambda dt, dl: fired.append(dt))
    assert fired and w.fired == 1
