"""Lineage reuse: signatures, index reshaping, automatic prediction (§VI)."""

import numpy as np
import pytest

from repro.core.capture import identity_lineage, reduce_lineage
from repro.core.catalog import DSLog
from repro.core.oplib import OPS
from repro.core.provrc import compress
from repro.core.query import QueryBox, theta_join
from repro.core.reuse import generalize, instantiate, symbolic_tables_equal


def test_index_reshaping_aggregate():
    """Paper Fig 6: [0, d-1] -> [0, D-1] symbolic, instantiate at new d."""
    t = compress(reduce_lineage((4,), 0))  # 1-D aggregate over 4 cells
    g = generalize(t)
    assert g.is_symbolic
    inst = instantiate(g, (1,), (9,))
    rel9 = inst.decompress()
    assert rel9 == reduce_lineage((9,), 0).canonical()


def test_index_reshaping_elementwise():
    t = compress(identity_lineage((5, 3)))
    g = generalize(t)
    inst = instantiate(g, (7, 2), (7, 2))
    assert inst.decompress() == identity_lineage((7, 2)).canonical()


def test_symbolic_equality_across_shapes():
    g1 = generalize(compress(identity_lineage((5,))))
    g2 = generalize(compress(identity_lineage((11,))))
    assert symbolic_tables_equal(g1, g2)
    g3 = generalize(compress(reduce_lineage((5,), 0)))
    assert not symbolic_tables_equal(g1, g3)


def _register(log, op, arrs, shape, lineage_fn, reuse=None, op_args=None):
    a, b = arrs
    log.define_array(a, shape[0])
    log.define_array(b, shape[1])
    calls = {"n": 0}

    def capture():
        calls["n"] += 1
        return {(0, 0): lineage_fn()}

    rec = log.register_operation(op, [a], [b], capture=capture, op_args=op_args, reuse=reuse)
    return rec, calls


def test_dim_sig_promotion_after_m_confirmations():
    log = DSLog(reuse_m=1)
    mk = lambda: identity_lineage((6, 4))
    r1, _ = _register(log, "neg", ("a1", "b1"), (((6, 4)), ((6, 4))), mk)
    assert r1.reused is None
    r2, _ = _register(log, "neg", ("a2", "b2"), (((6, 4)), ((6, 4))), mk)
    assert r2.reused is None  # confirmation call, captured + matched
    r3, c3 = _register(log, "neg", ("a3", "b3"), (((6, 4)), ((6, 4))), mk)
    assert r3.reused == "dim"
    assert c3["n"] == 0  # capture bypassed


def test_gen_sig_needs_distinct_shapes():
    log = DSLog(reuse_m=1)
    r1, _ = _register(log, "neg", ("x1", "y1"), ((4, 2), (4, 2)),
                      lambda: identity_lineage((4, 2)))
    # same shape again: dim tentative->confirmed on 3rd; gen needs new shape
    _register(log, "neg", ("x2", "y2"), ((4, 2), (4, 2)),
              lambda: identity_lineage((4, 2)))
    r3, _ = _register(log, "neg", ("x3", "y3"), ((9, 5), (9, 5)),
                      lambda: identity_lineage((9, 5)))
    assert r3.reused is None  # new shape confirms gen_sig
    log.define_array("x4", (3, 7))
    log.define_array("y4", (3, 7))
    r4 = log.register_operation("neg", ["x4"], ["y4"], capture=None)
    assert r4.reused == "gen"
    res = log.prov_query(["y4", "x4"], np.array([[2, 6]]))
    assert res.cell_set() == {(2, 6)}


def test_misprediction_cross_pattern():
    """The paper's `cross` error: pattern changes with the trailing dim, so
    a gen_sig generalized from 3-vectors must be detected as wrong."""
    spec = OPS["cross"]
    rng = np.random.default_rng(0)
    log = DSLog(reuse_m=1)

    def reg(nm_suffix, shape):
        rels = spec.lineage(shape, rng)
        n_out = rels[(0, 0)].out_shape
        log.define_array(f"a{nm_suffix}", shape)
        log.define_array(f"b{nm_suffix}", shape)
        log.define_array(f"o{nm_suffix}", n_out)
        return log.register_operation(
            "cross",
            [f"a{nm_suffix}", f"b{nm_suffix}"],
            [f"o{nm_suffix}"],
            capture=lambda: {(0, 0): rels[(0, 0)], (0, 1): rels[(0, 1)]},
        )

    reg(1, (6, 3))
    reg(2, (9, 3))  # different shape, same 3-vector pattern -> gen confirmed
    from repro.core.reuse import sig_key_gen

    assert log.predictor.status(sig_key_gen("cross", None)) == "confirmed"
    # a 2-vector call now WOULD be served wrongly by gen_sig: this is the
    # paper's documented misprediction. The coverage benchmark counts it.
    r3 = reg(3, (7, 2))
    assert r3.reused == "gen"  # reused — and the stored lineage is wrong
    stored = log.lineage[r3.lineage_ids[0]].backward
    true_rel = spec.lineage((7, 2), rng)[(0, 0)]
    assert stored.decompress() != true_rel.canonical()


def test_value_dependent_op_rejected():
    """Sort lineage differs between calls -> dim/gen must be rejected."""
    from repro.core.capture import sort_lineage

    rng = np.random.default_rng(0)
    log = DSLog(reuse_m=1)
    for i in range(2):
        log.define_array(f"s{i}", (16,))
        log.define_array(f"t{i}", (16,))
        vals = rng.random(16)
        log.register_operation(
            "sort", [f"s{i}"], [f"t{i}"],
            capture=lambda v=vals: {(0, 0): sort_lineage(v)},
        )
    from repro.core.reuse import sig_key_dim, sig_key_gen

    assert log.predictor.status(sig_key_dim("sort", ((16,), (16,)), None)) == "rejected"
    assert log.predictor.status(sig_key_gen("sort", None)) == "rejected"


def test_reused_tables_answer_queries():
    log = DSLog(reuse_m=1)
    for i, shape in enumerate([(4, 3), (4, 3), (4, 3)]):
        log.define_array(f"in{i}", shape)
        log.define_array(f"out{i}", (shape[0],))
        log.register_operation(
            "sumax1", [f"in{i}"], [f"out{i}"],
            capture=lambda s=shape: {(0, 0): reduce_lineage(s, 1)},
            op_args={"axis": 1},
        )
    assert log.ops[-1].reused == "dim"
    res = log.prov_query(["out2", "in2"], np.array([[1]]))
    assert res.cell_set() == {(1, j) for j in range(3)}
