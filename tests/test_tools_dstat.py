"""``dstat`` — the telemetry-sidecar inspector CLI.

``diff`` gets a golden-output test (two hand-built snapshots, exact stdout)
and ``watch`` a single-iteration smoke test — its loop is driven with
``--count`` so the test never sleeps past one interval.
"""

import json

from repro.tools import dstat

_SCHEMA = "dslog-telemetry/v1"


def _snap(counters, histograms=(), gauges=()):
    return {
        "schema": _SCHEMA,
        "store": "DSLog",
        "registry": "dslog",
        "root": "/tmp/s",
        "generated_at": 0.0,
        "counters": [
            {"name": n, "labels": dict(labels), "value": v}
            for n, labels, v in counters
        ],
        "gauges": [
            {"name": n, "labels": dict(labels), "value": v}
            for n, labels, v in gauges
        ],
        "histograms": [
            {
                "name": n,
                "labels": dict(labels),
                "count": c,
                "sum": float(c),
                "min": 1.0,
                "max": 1.0,
                "p50": 1.0,
                "p90": 1.0,
                "p99": 1.0,
                "buckets": [[0, c]],
            }
            for n, labels, c in histograms
        ],
    }


def _write(path, snap) -> str:
    path.write_text(json.dumps(snap))
    return str(path)


OLD = _snap(
    counters=[
        ("wal_appends", {}, 10),
        ("cache_hits", {"route": "a->b"}, 4),
        ("dropped", {}, 1),
    ],
    histograms=[("flush_seconds", {}, 3)],
)
NEW = _snap(
    counters=[
        ("wal_appends", {}, 25),
        ("cache_hits", {"route": "a->b"}, 4),  # unchanged: omitted
        ("dropped", {}, 1),
        ("queries", {}, 7),  # new counter diffs against zero
    ],
    histograms=[("flush_seconds", {}, 9)],
)


def test_diff_snapshots_counter_and_histogram_deltas():
    delta = dstat.diff_snapshots(OLD, NEW)
    assert delta == {
        "counters": {"queries": 7, "wal_appends": 15},
        "histograms": {"flush_seconds": 6},
    }


def test_diff_golden_output(tmp_path, capsys):
    old = _write(tmp_path / "old.json", OLD)
    new = _write(tmp_path / "new.json", NEW)
    rc = dstat.main(["diff", old, new])
    assert rc == 0
    assert capsys.readouterr().out == (
        "counters:\n"
        "  queries  +7\n"
        "  wal_appends  +15\n"
        "histograms:\n"
        "  flush_seconds  +6\n"
    )


def test_diff_json_and_no_change(tmp_path, capsys):
    old = _write(tmp_path / "old.json", OLD)
    rc = dstat.main(["diff", old, old, "--json"])
    assert rc == 0
    assert json.loads(capsys.readouterr().out) == {
        "counters": {},
        "histograms": {},
    }
    rc = dstat.main(["diff", old, old])
    assert rc == 0
    assert capsys.readouterr().out == "no change\n"


def test_diff_resolves_store_root(tmp_path, capsys):
    """A directory operand resolves to its telemetry.json sidecar."""
    _write(tmp_path / "telemetry.json", OLD)
    new = _write(tmp_path / "new.json", NEW)
    rc = dstat.main(["diff", str(tmp_path), new])
    assert rc == 0
    assert "wal_appends  +15" in capsys.readouterr().out


def test_watch_single_iteration_smoke(tmp_path, capsys):
    """One read (--count 1): prints the full first snapshot, then stops
    without sleeping."""
    target = _write(tmp_path / "telemetry.json", OLD)
    rc = dstat.main(["watch", target, "--count", "1", "--interval", "0"])
    assert rc == 0
    out = capsys.readouterr().out
    assert "counters:" in out
    assert "wal_appends" in out
    assert "[" not in out  # no delta lines on the first read


def test_watch_two_reads_reports_no_change(tmp_path, capsys):
    target = _write(tmp_path / "telemetry.json", OLD)
    rc = dstat.main(["watch", target, "--count", "2", "--interval", "0"])
    assert rc == 0
    assert "(no change)" in capsys.readouterr().out


def test_dump_rejects_invalid_snapshot(tmp_path, capsys):
    bad = tmp_path / "telemetry.json"
    bad.write_text(json.dumps({"schema": "nope"}))
    rc = dstat.main(["dump", str(bad)])
    assert rc == 2
    assert "invalid telemetry" in capsys.readouterr().err


def test_missing_file_exit_code(tmp_path):
    rc = dstat.main(["diff", str(tmp_path / "a.json"), str(tmp_path / "b.json")])
    assert rc == 2
