"""Test-suite bootstrap: optional-dependency fallbacks.

``hypothesis`` is an *optional* dependency (see requirements.txt): when it is
missing, install the minimal seeded-random shim from ``_propshim`` into
``sys.modules`` before any test module is collected, so the property-based
modules still import and their properties still run (with reduced example
counts and no shrinking).
"""

import os
import sys

import pytest

sys.path.insert(0, os.path.dirname(__file__))

try:
    import hypothesis  # noqa: F401
except ImportError:
    import _propshim

    _propshim.install()


@pytest.fixture
def race_detector(monkeypatch):
    """Run the test under the dynamic lock-order / race detector.

    Sets ``DSLOG_RACE_DETECT=1`` so every lock ``repro.core._locks`` mints
    during the test is instrumented (``repro.tools.racecheck``), then
    asserts at teardown that no lock-order violation, acquisition-graph
    cycle, or unguarded shared-state mutation was recorded.  Modules opt in
    with an autouse wrapper fixture.
    """
    from repro.tools import racecheck

    monkeypatch.setenv("DSLOG_RACE_DETECT", "1")
    racecheck.reset()
    yield racecheck
    findings = racecheck.findings()
    # static↔dynamic cross-check: CI sets DSLOG_RACE_EXPORT to a path and
    # later runs `dsflow --check-dynamic` on the accumulated edge graph
    export = os.environ.get("DSLOG_RACE_EXPORT")
    if export:
        racecheck.export_edges(export)
    racecheck.reset()
    assert not findings, "race-detector findings:\n" + "\n".join(findings)
