"""Test-suite bootstrap: optional-dependency fallbacks.

``hypothesis`` is an *optional* dependency (see requirements.txt): when it is
missing, install the minimal seeded-random shim from ``_propshim`` into
``sys.modules`` before any test module is collected, so the property-based
modules still import and their properties still run (with reduced example
counts and no shrinking).
"""

import os
import sys

sys.path.insert(0, os.path.dirname(__file__))

try:
    import hypothesis  # noqa: F401
except ImportError:
    import _propshim

    _propshim.install()
