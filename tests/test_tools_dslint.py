"""dslint rules: fixture-backed positive/negative pairs, pragmas, CLI.

Each rule gets (at least) one fixture module that must trigger it and one
that must not.  Fixtures are written into a fake ``repro/...`` tree under
``tmp_path`` so the path-scoped rules see the scopes they key on.
"""

import os
import subprocess
import sys

import pytest

from repro.tools import dslint

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def _lint(tmp_path, relpath: str, source: str):
    path = tmp_path / relpath
    path.parent.mkdir(parents=True, exist_ok=True)
    path.write_text(source)
    return dslint.lint_file(str(path))


def _rules(findings):
    return {f.rule for f in findings}


# --------------------------------------------------------------------------- #
# lock-context
# --------------------------------------------------------------------------- #
def test_lock_context_flags_bare_acquire(tmp_path):
    findings = _lint(
        tmp_path,
        "repro/core/wal.py",
        "def f(self):\n"
        "    self._lock.acquire()\n"
        "    try:\n"
        "        pass\n"
        "    finally:\n"
        "        self._lock.release()\n",
    )
    assert _rules(findings) == {"lock-context"}
    assert len(findings) == 2  # both the acquire and the release


def test_lock_context_allows_with(tmp_path):
    findings = _lint(
        tmp_path,
        "repro/core/wal.py",
        "def f(self):\n    with self._lock:\n        pass\n",
    )
    assert "lock-context" not in _rules(findings)


def test_lock_context_ignores_non_lock_acquire(tmp_path):
    findings = _lint(
        tmp_path,
        "repro/core/commit.py",
        "def f(self, root):\n    return WriterLease.acquire(root)\n",
    )
    assert "lock-context" not in _rules(findings)


# --------------------------------------------------------------------------- #
# lock-order
# --------------------------------------------------------------------------- #
def test_lock_order_flags_inverted_nesting(tmp_path):
    findings = _lint(
        tmp_path,
        "repro/core/commit.py",
        "def f(self):\n"
        "    with self._lock:\n"          # commit._lock, rank 40
        "        with self._flush_mutex:\n"  # rank 30 -> violation
        "            pass\n",
    )
    assert "lock-order" in _rules(findings)


def test_lock_order_allows_declared_nesting(tmp_path):
    findings = _lint(
        tmp_path,
        "repro/core/commit.py",
        "def f(self):\n"
        "    with self._flush_mutex:\n"
        "        with self._lock:\n"
        "            pass\n",
    )
    assert not findings


def test_lock_order_flags_undeclared_lock(tmp_path):
    findings = _lint(
        tmp_path,
        "repro/core/somewhere.py",
        "def f(self):\n    with self._secret_lock:\n        pass\n",
    )
    assert "lock-order" in _rules(findings)
    assert "not in the declared lock-order table" in findings[0].message


def test_lock_order_resets_at_function_boundary(tmp_path):
    findings = _lint(
        tmp_path,
        "repro/core/commit.py",
        "def f(self):\n"
        "    with self._lock:\n"
        "        def g():\n"
        "            with self._flush_mutex:\n"
        "                pass\n",
    )
    assert "lock-order" not in _rules(findings)


# --------------------------------------------------------------------------- #
# lock-new
# --------------------------------------------------------------------------- #
def test_lock_new_flags_direct_construction(tmp_path):
    findings = _lint(
        tmp_path,
        "repro/core/wal.py",
        "import threading\n\n"
        "def f(self):\n    self._lock = threading.Lock()\n",
    )
    assert "lock-new" in _rules(findings)


def test_lock_new_allows_factory_and_locks_module(tmp_path):
    clean = _lint(
        tmp_path,
        "repro/core/wal.py",
        "from . import _locks\n\n"
        "def f(self):\n    self._lock = _locks.new_lock('wal._lock')\n",
    )
    assert "lock-new" not in _rules(clean)
    exempt = _lint(
        tmp_path,
        "repro/core/_locks.py",
        "import threading\n\ndef new_lock(name):\n    return threading.Lock()\n",
    )
    assert "lock-new" not in _rules(exempt)


# --------------------------------------------------------------------------- #
# atomic-manifest
# --------------------------------------------------------------------------- #
def test_atomic_manifest_flags_text_write(tmp_path):
    findings = _lint(
        tmp_path,
        "repro/core/catalog.py",
        "def save(self, path, payload):\n"
        "    with open(path, 'w') as f:\n"
        "        f.write(payload)\n",
    )
    assert "atomic-manifest" in _rules(findings)


def test_atomic_manifest_allows_atomic_write_and_reads(tmp_path):
    findings = _lint(
        tmp_path,
        "repro/core/catalog.py",
        "import os\n\n"
        "def _atomic_write(path, payload):\n"
        "    with open(path + '.tmp', 'w') as f:\n"
        "        f.write(payload)\n"
        "        f.flush()\n"
        "        os.fsync(f.fileno())\n"
        "    os.replace(path + '.tmp', path)\n\n"
        "def load(path):\n"
        "    with open(path) as f:\n"
        "        return f.read()\n",
    )
    assert "atomic-manifest" not in _rules(findings)


# --------------------------------------------------------------------------- #
# fsync-blob
# --------------------------------------------------------------------------- #
def test_fsync_blob_flags_unfsynced_binary_write(tmp_path):
    findings = _lint(
        tmp_path,
        "repro/core/catalog.py",
        "def _write_entry(self, path, blob):\n"
        "    with open(path, 'wb') as f:\n"
        "        f.write(blob)\n",
    )
    assert "fsync-blob" in _rules(findings)


def test_fsync_blob_allows_fsynced_write(tmp_path):
    findings = _lint(
        tmp_path,
        "repro/core/catalog.py",
        "import os\n\n"
        "def _write_blob(self, path, blob):\n"
        "    with open(path, 'wb') as f:\n"
        "        f.write(blob)\n"
        "        f.flush()\n"
        "        os.fsync(f.fileno())\n",
    )
    assert "fsync-blob" not in _rules(findings)


def test_fsync_blob_out_of_scope_module_unchecked(tmp_path):
    findings = _lint(
        tmp_path,
        "repro/core/wal.py",
        "def dump(path, blob):\n"
        "    with open(path, 'wb') as f:\n"
        "        f.write(blob)\n",
    )
    assert "fsync-blob" not in _rules(findings)


# --------------------------------------------------------------------------- #
# bare-except / mutable-default
# --------------------------------------------------------------------------- #
def test_bare_except_flagged(tmp_path):
    findings = _lint(
        tmp_path,
        "repro/core/util.py",
        "def f():\n    try:\n        pass\n    except:\n        pass\n",
    )
    assert "bare-except" in _rules(findings)


def test_typed_except_allowed(tmp_path):
    findings = _lint(
        tmp_path,
        "repro/core/util.py",
        "def f():\n    try:\n        pass\n    except ValueError:\n        pass\n",
    )
    assert "bare-except" not in _rules(findings)


def test_mutable_default_flagged(tmp_path):
    findings = _lint(
        tmp_path,
        "repro/kernels/util.py",
        "def f(xs=[]):\n    return xs\n\n"
        "def g(*, m={}):\n    return m\n\n"
        "def h(s=set()):\n    return s\n",
    )
    assert sum(1 for f in findings if f.rule == "mutable-default") == 3


def test_none_default_allowed(tmp_path):
    findings = _lint(
        tmp_path,
        "repro/kernels/util.py",
        "def f(xs=None, n=3, name='x'):\n    return xs\n",
    )
    assert "mutable-default" not in _rules(findings)


# --------------------------------------------------------------------------- #
# int32-cast
# --------------------------------------------------------------------------- #
def test_int32_cast_flagged_without_guard(tmp_path):
    findings = _lint(
        tmp_path,
        "repro/kernels/pack.py",
        "import numpy as np\n\n"
        "def pack(lo):\n    return lo.astype(np.int32)\n",
    )
    assert "int32-cast" in _rules(findings)


def test_int32_cast_allowed_with_guard(tmp_path):
    findings = _lint(
        tmp_path,
        "repro/kernels/pack.py",
        "import numpy as np\n\n"
        "def pack(lo):\n"
        "    _require_int32(lo)\n"
        "    return lo.astype(np.int32)\n",
    )
    assert "int32-cast" not in _rules(findings)


def test_int32_cast_out_of_scope_unchecked(tmp_path):
    findings = _lint(
        tmp_path,
        "repro/core/catalog.py",
        "import numpy as np\n\n"
        "def f(x):\n    return x.astype(np.int32)\n",
    )
    assert "int32-cast" not in _rules(findings)


# --------------------------------------------------------------------------- #
# metric-registry
# --------------------------------------------------------------------------- #
def test_metric_registry_flags_subscript_writes(tmp_path):
    findings = _lint(
        tmp_path,
        "repro/core/catalog.py",
        "def f(self, n):\n"
        '    self.io_stats["tables_loaded"] += 1\n'
        '    self._io["bytes_written"] = n\n'
        '    self.wal.stats["records"] = 0\n',
    )
    assert [f.rule for f in findings] == ["metric-registry"] * 3


def test_metric_registry_flags_dict_mutators(tmp_path):
    findings = _lint(
        tmp_path,
        "repro/core/shard.py",
        "def f(self):\n"
        '    self.io_stats.update({"cache_hits": 1})\n'
        "    self.stats.clear()\n",
    )
    assert [f.rule for f in findings] == ["metric-registry"] * 2


def test_metric_registry_allows_registry_and_local_dicts(tmp_path):
    findings = _lint(
        tmp_path,
        "repro/core/catalog.py",
        "def f(self, key, n):\n"
        "    self.metrics.inc(key, n)\n"
        '    self.hop_stats[key] = (1.0, 2.0)\n'  # guarded EMA table, exempt
        '    stats = {"files_removed": 0}\n'
        '    stats["files_removed"] += 1\n'  # local dict, not an instrument
        "    return self.io_stats[key]\n",  # reads stay legal
    )
    assert "metric-registry" not in _rules(findings)


def test_metric_registry_out_of_scope_unchecked(tmp_path):
    findings = _lint(
        tmp_path,
        "repro/tools/bench.py",
        'def f(log):\n    log.io_stats["cache_hits"] = 0\n',
    )
    assert "metric-registry" not in _rules(findings)


def test_metric_registry_pragma_escape(tmp_path):
    findings = _lint(
        tmp_path,
        "repro/core/catalog.py",
        "def f(self):\n"
        '    self.io_stats["x"] = 1  # dslint: ignore[metric-registry]\n',
    )
    assert "metric-registry" not in _rules(findings)


# --------------------------------------------------------------------------- #
# pragmas, plugins, driver
# --------------------------------------------------------------------------- #
def test_pragma_suppresses_named_rule(tmp_path):
    findings = _lint(
        tmp_path,
        "repro/core/util.py",
        "def f():\n"
        "    try:\n"
        "        pass\n"
        "    except:  # dslint: ignore[bare-except]\n"
        "        pass\n",
    )
    assert "bare-except" not in _rules(findings)


def test_pragma_on_previous_line_suppresses(tmp_path):
    findings = _lint(
        tmp_path,
        "repro/kernels/pack.py",
        "import numpy as np\n\n"
        "def pack(lo):\n"
        "    # dslint: ignore[int32-cast]\n"
        "    return lo.astype(np.int32)\n",
    )
    assert "int32-cast" not in _rules(findings)


def test_pragma_does_not_suppress_other_rules(tmp_path):
    findings = _lint(
        tmp_path,
        "repro/core/util.py",
        "def f():\n"
        "    try:\n"
        "        pass\n"
        "    except:  # dslint: ignore[mutable-default]\n"
        "        pass\n",
    )
    assert "bare-except" in _rules(findings)


def test_blanket_pragma_suppresses_all(tmp_path):
    findings = _lint(
        tmp_path,
        "repro/core/util.py",
        "def f():\n"
        "    try:\n"
        "        pass\n"
        "    except:  # dslint: ignore\n"
        "        pass\n",
    )
    assert not findings


def test_rules_are_pluggable(tmp_path):
    class NoTodoRule:
        name = "no-todo"

        def applies(self, scope):
            return True

        def check(self, ctx):
            for i, line in enumerate(ctx.source.splitlines(), start=1):
                if "TODO" in line:
                    yield dslint.Finding(ctx.path, i, self.name, "TODO found")

    dslint.register(NoTodoRule())
    try:
        findings = _lint(tmp_path, "repro/core/x.py", "# TODO: later\n")
        assert "no-todo" in _rules(findings)
    finally:
        dslint.RULES.pop()


def test_repo_tree_is_clean():
    """The merged tree lints clean — the CI gate in test form."""
    findings = dslint.lint_paths([SRC])
    assert findings == [], "\n".join(str(f) for f in findings)


def test_cli_exit_codes(tmp_path):
    bad = tmp_path / "repro" / "core" / "bad.py"
    bad.parent.mkdir(parents=True)
    bad.write_text("def f():\n    try:\n        pass\n    except:\n        pass\n")
    env = dict(os.environ, PYTHONPATH=os.path.abspath(SRC))
    r = subprocess.run(
        [sys.executable, "-m", "repro.tools.dslint", str(tmp_path)],
        capture_output=True,
        text=True,
        env=env,
    )
    assert r.returncode == 1
    assert "bare-except" in r.stdout
    bad.write_text("def f():\n    pass\n")
    r = subprocess.run(
        [sys.executable, "-m", "repro.tools.dslint", str(tmp_path)],
        capture_output=True,
        text=True,
        env=env,
    )
    assert r.returncode == 0, r.stdout + r.stderr
