"""In-situ query processing == oracle over uncompressed rows (paper §V)."""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.capture import identity_lineage, reduce_lineage, softmax_lineage
from repro.core.provrc import compress, compress_both
from repro.core.query import QueryBox, merge_boxes, theta_join, theta_join_inverse
from repro.core.relation import LineageRelation


def oracle_backward(rel, cells):
    cells = {tuple(c) for c in cells}
    return {tuple(r) for o, r in zip(rel.out_idx, rel.in_idx) if tuple(o) in cells}


def oracle_forward(rel, cells):
    cells = {tuple(c) for c in cells}
    return {tuple(o) for o, r in zip(rel.out_idx, rel.in_idx) if tuple(r) in cells}


@settings(max_examples=60, deadline=None)
@given(data=st.data(), method=st.sampled_from(["paper", "vector"]))
def test_in_situ_equals_oracle(data, method):
    l = data.draw(st.integers(1, 2))
    m = data.draw(st.integers(1, 2))
    oshape = tuple(data.draw(st.integers(2, 5)) for _ in range(l))
    ishape = tuple(data.draw(st.integers(2, 5)) for _ in range(m))
    n = data.draw(st.integers(1, 50))
    rng = np.random.default_rng(data.draw(st.integers(0, 2**31)))
    o = np.stack([rng.integers(0, s, n) for s in oshape], axis=1)
    i = np.stack([rng.integers(0, s, n) for s in ishape], axis=1)
    rel = LineageRelation(oshape, ishape, o, i).canonical()
    bwd, fwd = compress_both(rel, method=method)

    qo = np.unique(np.stack([rng.integers(0, s, 3) for s in oshape], axis=1), axis=0)
    qi = np.unique(np.stack([rng.integers(0, s, 3) for s in ishape], axis=1), axis=0)
    q_out = QueryBox.from_cells(oshape, qo)
    q_in = QueryBox.from_cells(ishape, qi)

    assert theta_join(q_out, bwd).cell_set() == oracle_backward(rel, qo)
    assert theta_join(q_in, fwd).cell_set() == oracle_forward(rel, qi)
    # rel_for path: inverse joins against the opposite materialization
    assert theta_join_inverse(q_in, bwd).cell_set() == oracle_forward(rel, qi)
    assert theta_join_inverse(q_out, fwd).cell_set() == oracle_backward(rel, qo)


def test_range_query_boxes():
    """Queries are boxes, not cell lists — intersect semantics (paper Fig 4)."""
    rel = reduce_lineage((8, 4), 1)  # out[i] <- in[i, :]
    bwd = compress(rel)
    q = QueryBox.from_range((8,), (2,), (5,))
    res = theta_join(q, bwd)
    assert res.cell_set() == {(i, j) for i in range(2, 6) for j in range(4)}
    # merged result should stay compact (one box)
    assert res.n_rows == 1


def test_multi_hop_path():
    relXY = identity_lineage((6, 3))
    relYZ = reduce_lineage((6, 3), 1)
    tXY_b = compress(relXY, "backward")
    tYZ_b = compress(relYZ, "backward")
    q = QueryBox.from_cells((6,), np.array([[4]]))
    mid = theta_join(q, tYZ_b)
    res = theta_join(mid, tXY_b)
    assert res.cell_set() == {(4, j) for j in range(3)}


def test_merge_reduces_rows_nomerge_ablation():
    rel = softmax_lineage((4, 16), -1)
    bwd = compress(rel)
    cells = np.array([[1, j] for j in range(16)])
    q = QueryBox.from_cells((4, 16), cells)
    merged = theta_join(q, bwd, merge=True)
    unmerged = theta_join(q, bwd, merge=False)
    assert merged.cell_set() == unmerged.cell_set()
    assert merged.n_rows < unmerged.n_rows  # DSLog vs DSLog-NoMerge


def test_merge_boxes_unions_overlaps():
    q = QueryBox((10,), np.array([[0], [3], [5], [2]]), np.array([[4], [6], [9], [3]]))
    m = merge_boxes(q)
    assert m.n_rows == 1
    assert (m.lo[0, 0], m.hi[0, 0]) == (0, 9)


def test_empty_query():
    rel = identity_lineage((5,))
    bwd = compress(rel)
    q = QueryBox((5,), np.zeros((0, 1)), np.zeros((0, 1)))
    assert theta_join(q, bwd).n_rows == 0


def test_shape_mismatch_raises():
    bwd = compress(identity_lineage((5,)))
    with pytest.raises(ValueError):
        theta_join(QueryBox.from_cells((4,), np.array([[0]])), bwd)


def test_diagonal_relation_not_overcounted():
    """Regression: diagonal lineage (two value attrs that could both merge
    as deltas against the same key) must NOT be over-approximated to its
    bounding box by the θ-join (the ≤1-delta-per-key encode invariant)."""
    # out (i, 7) <- in (i, 90 + i): a diagonal in both attrs
    rows = [((i, 7), (i, 90 + i)) for i in range(10)]
    rel = LineageRelation.from_pairs((10, 8), (10, 100), rows)
    for method in ("paper", "vector"):
        t = compress(rel, "backward", method)
        assert t.decompress() == rel
        # per-row ref uniqueness invariant
        for r in range(t.n_rows):
            refs = [x for x in t.val_ref[r] if x >= 0]
            assert len(refs) == len(set(refs)), "two deltas on one key"
        q = QueryBox.from_range((10, 8), (2, 7), (3, 7))
        got = theta_join(q, t).cell_set()
        assert got == {(2, 92), (3, 93)}, got
